//! Time-shared resource scheduling — the paper's Fig 7 event handler and
//! Fig 8 PE-share allocation, reproduced exactly.
//!
//! All Gridlets execute concurrently and share the PEs round-robin. Between
//! events the simulator advances every Gridlet by its *share* of the
//! available MIPS; on each arrival/completion the shares are recomputed and
//! a completion interrupt is (re)scheduled at the earliest forecast finish.
//!
//! Share allocation with `n` Gridlets on `p` PEs (Fig 8):
//! * `n ≤ p`: every Gridlet gets a full PE (`MIPS · Δt` MI per interval);
//! * `n > p`: `min_per_pe = ⌊n/p⌋`, `extra = n mod p`;
//!   `(p − extra) · min_per_pe` Gridlets (the earliest arrivals) receive
//!   `MIPS·Δt / min_per_pe`, the remaining Gridlets receive
//!   `MIPS·Δt / (min_per_pe + 1)`.

use super::gridlet::GridletStatus;
use super::res_gridlet::ResGridlet;
use super::resource::LocalScheduler;

/// Time-shared (round-robin multitasking) scheduler state.
#[derive(Debug)]
pub struct TimeShared {
    /// PEs in the resource.
    num_pe: usize,
    /// MIPS rating of one PE.
    mips_per_pe: f64,
    /// PEs withheld (active advance reservations / failures).
    withheld_pe: usize,
    /// Execution set, kept in arrival-rank order.
    exec: Vec<ResGridlet>,
    /// Last time `advance` ran (share bookkeeping anchor).
    last_time: f64,
    /// Availability factor (1 − local load) in effect since `last_time`.
    availability: f64,
}

impl TimeShared {
    /// A round-robin scheduler over `num_pe` PEs rated `mips_per_pe` each.
    pub fn new(num_pe: usize, mips_per_pe: f64) -> TimeShared {
        assert!(num_pe >= 1);
        assert!(mips_per_pe > 0.0);
        TimeShared {
            num_pe,
            mips_per_pe,
            withheld_pe: 0,
            exec: Vec::new(),
            last_time: 0.0,
            availability: 1.0,
        }
    }

    /// Effective PEs currently usable by grid work.
    fn effective_pe(&self) -> usize {
        (self.num_pe - self.withheld_pe).max(1)
    }

    /// Per-Gridlet processing rates (MI per time unit) under Fig 8, in the
    /// order of `self.exec`.
    fn rates(&self) -> Vec<f64> {
        let n = self.exec.len();
        let p = self.effective_pe();
        let eff = self.mips_per_pe * self.availability;
        if n == 0 {
            return Vec::new();
        }
        if n <= p {
            return vec![eff; n];
        }
        let min_per_pe = n / p;
        let extra = n % p;
        let max_share_count = (p - extra) * min_per_pe;
        let max_rate = eff / min_per_pe as f64;
        let min_rate = eff / (min_per_pe + 1) as f64;
        (0..n).map(|i| if i < max_share_count { max_rate } else { min_rate }).collect()
    }

    /// Advance all executing Gridlets from `last_time` to `now`, consuming
    /// their PE shares ("Allocate PE Share for Gridlets Processed so far").
    fn advance(&mut self, now: f64) {
        let elapsed = now - self.last_time;
        if elapsed > 0.0 && !self.exec.is_empty() {
            let rates = self.rates();
            for (rg, rate) in self.exec.iter_mut().zip(rates) {
                rg.consume(rate * elapsed);
            }
        }
        self.last_time = self.last_time.max(now);
    }

    /// Test/inspection hook: remaining MI per gridlet in rank order.
    pub fn remaining(&self) -> Vec<f64> {
        self.exec.iter().map(|rg| rg.remaining_mi).collect()
    }

    /// Pull the job at `idx` out of the execution set, charging for the
    /// work actually consumed (shared by both cancel entry points; the
    /// caller has already advanced the shares to `now`).
    fn cancel_at(&mut self, idx: usize, now: f64) -> ResGridlet {
        let mut rg = self.exec.remove(idx);
        rg.gridlet.status = GridletStatus::Canceled;
        rg.gridlet.finish_time = now;
        // Charge for the work actually consumed.
        rg.gridlet.cpu_time = (rg.gridlet.length_mi - rg.remaining_mi) / self.mips_per_pe;
        rg
    }
}

impl LocalScheduler for TimeShared {
    fn set_availability(&mut self, factor: f64, now: f64) {
        // Piecewise-constant background load: settle the old interval at the
        // old factor, then switch.
        self.advance(now);
        self.availability = factor.clamp(0.0, 1.0).max(1e-9);
    }

    fn set_withheld_pes(&mut self, pes: usize, now: f64) {
        self.advance(now);
        self.withheld_pe = pes.min(self.num_pe.saturating_sub(1));
    }

    fn submit(&mut self, mut rg: ResGridlet, now: f64) {
        self.advance(now);
        rg.start = now;
        rg.gridlet.status = GridletStatus::InExec;
        // Time-shared systems start every job immediately (paper §3.5.1).
        self.exec.push(rg);
    }

    fn collect(&mut self, now: f64) -> Vec<ResGridlet> {
        self.advance(now);
        let mut done = Vec::new();
        let mut i = 0;
        while i < self.exec.len() {
            if self.exec[i].is_done() {
                let mut rg = self.exec.remove(i);
                rg.remaining_mi = 0.0;
                rg.gridlet.status = GridletStatus::Success;
                rg.gridlet.finish_time = now;
                rg.gridlet.cpu_time = rg.gridlet.length_mi / self.mips_per_pe;
                done.push(rg);
            } else {
                i += 1;
            }
        }
        done
    }

    fn next_completion(&mut self, now: f64) -> Option<f64> {
        self.advance(now);
        let rates = self.rates();
        self.exec
            .iter()
            .zip(rates)
            .map(|(rg, rate)| now + rg.remaining_mi / rate)
            .min_by(|a, b| a.total_cmp(b))
    }

    fn in_exec(&self) -> usize {
        self.exec.len()
    }

    fn queued(&self) -> usize {
        0 // time-shared resources never queue (paper §3.5.1)
    }

    fn cancel(&mut self, gridlet_id: usize, now: f64) -> Option<ResGridlet> {
        self.advance(now);
        let idx = self.exec.iter().position(|rg| rg.gridlet.id == gridlet_id)?;
        Some(self.cancel_at(idx, now))
    }

    fn cancel_owned(
        &mut self,
        owner: crate::des::EntityId,
        gridlet_id: usize,
        now: f64,
    ) -> Option<ResGridlet> {
        self.advance(now);
        let idx = self
            .exec
            .iter()
            .position(|rg| rg.gridlet.owner == owner && rg.gridlet.id == gridlet_id)?;
        Some(self.cancel_at(idx, now))
    }

    fn status_of(&self, gridlet_id: usize) -> Option<GridletStatus> {
        self.exec
            .iter()
            .find(|rg| rg.gridlet.id == gridlet_id)
            .map(|rg| rg.gridlet.status)
    }

    fn drain(&mut self, now: f64) -> Vec<ResGridlet> {
        self.advance(now);
        let mut all: Vec<ResGridlet> = std::mem::take(&mut self.exec);
        for rg in &mut all {
            rg.gridlet.status = GridletStatus::Lost;
            rg.gridlet.finish_time = now;
        }
        all
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gridsim::gridlet::Gridlet;

    fn rg(id: usize, mi: f64, now: f64, rank: u64) -> ResGridlet {
        ResGridlet::new(Gridlet::new(id, mi, 0, 0), now, rank)
    }

    /// The paper's Table 1 / Fig 9 scenario, step by step.
    #[test]
    fn table1_time_shared_exact() {
        let mut ts = TimeShared::new(2, 1.0);
        // t=0: G1 (10 MI) arrives.
        ts.submit(rg(1, 10.0, 0.0, 0), 0.0);
        assert_eq!(ts.next_completion(0.0), Some(10.0));
        // t=4: G2 (8.5 MI) arrives; both on separate PEs.
        ts.submit(rg(2, 8.5, 4.0, 1), 4.0);
        assert_eq!(ts.next_completion(4.0), Some(10.0)); // G1 still first
        // G2 predicted at 12.5 while n <= p.
        // t=7: G3 (9.5 MI) arrives; shares: G1 full PE, G2+G3 share PE2.
        ts.submit(rg(3, 9.5, 7.0, 2), 7.0);
        assert_eq!(ts.next_completion(7.0), Some(10.0));
        // t=10: G1 completes.
        let done = ts.collect(10.0);
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].gridlet.id, 1);
        assert_eq!(done[0].gridlet.finish_time, 10.0);
        // After G1 leaves: G2 has 4.0 left, G3 has 8.0; both full-PE now.
        assert_eq!(ts.remaining(), vec![4.0, 8.0]);
        assert_eq!(ts.next_completion(10.0), Some(14.0));
        // t=14: G2 completes (Table 1: finish 14, elapsed 10).
        let done = ts.collect(14.0);
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].gridlet.id, 2);
        assert_eq!(done[0].gridlet.finish_time, 14.0);
        assert_eq!(done[0].gridlet.elapsed(), 10.0);
        // t=18: G3 completes (Table 1: finish 18, elapsed 11).
        assert_eq!(ts.next_completion(14.0), Some(18.0));
        let done = ts.collect(18.0);
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].gridlet.id, 3);
        assert_eq!(done[0].gridlet.elapsed(), 11.0);
        assert_eq!(ts.in_exec(), 0);
    }

    #[test]
    fn fig8_share_allocation_5_jobs_2_pes() {
        // n=5, p=2: min_per_pe=2, extra=1, max_share_count=(2-1)*2=2.
        // First 2 gridlets at MIPS/2, remaining 3 at MIPS/3.
        let mut ts = TimeShared::new(2, 6.0);
        for i in 0..5 {
            ts.submit(rg(i, 60.0, 0.0, i as u64), 0.0);
        }
        let rates = ts.rates();
        assert_eq!(rates, vec![3.0, 3.0, 2.0, 2.0, 2.0]);
        // Total rate never exceeds aggregate MIPS.
        assert!((rates.iter().sum::<f64>() - 12.0).abs() < 1e-12);
    }

    #[test]
    fn single_pe_round_robin() {
        // Two equal jobs on one PE finish together at 2×(len/MIPS).
        let mut ts = TimeShared::new(1, 10.0);
        ts.submit(rg(0, 100.0, 0.0, 0), 0.0);
        ts.submit(rg(1, 100.0, 0.0, 1), 0.0);
        assert_eq!(ts.next_completion(0.0), Some(20.0));
        let done = ts.collect(20.0);
        assert_eq!(done.len(), 2);
    }

    #[test]
    fn cpu_time_is_length_over_mips() {
        let mut ts = TimeShared::new(1, 4.0);
        ts.submit(rg(0, 100.0, 0.0, 0), 0.0);
        ts.submit(rg(1, 100.0, 0.0, 1), 0.0);
        let done = ts.collect(50.0);
        for rg in &done {
            assert_eq!(rg.gridlet.cpu_time, 25.0); // 100 MI / 4 MIPS
            assert_eq!(rg.gridlet.finish_time, 50.0); // wall-clock doubled
        }
    }

    #[test]
    fn availability_scales_rates() {
        let mut ts = TimeShared::new(1, 10.0);
        ts.set_availability(0.5, 0.0);
        ts.submit(rg(0, 100.0, 0.0, 0), 0.0);
        // Effective 5 MIPS → done at t=20.
        assert_eq!(ts.next_completion(0.0), Some(20.0));
    }

    #[test]
    fn availability_change_mid_run_is_piecewise() {
        let mut ts = TimeShared::new(1, 10.0);
        ts.submit(rg(0, 100.0, 0.0, 0), 0.0);
        // Full speed until t=5 (50 MI done), then half speed.
        ts.set_availability(0.5, 5.0);
        assert_eq!(ts.remaining(), vec![50.0]);
        assert_eq!(ts.next_completion(5.0), Some(15.0));
    }

    #[test]
    fn cancel_charges_partial_work() {
        let mut ts = TimeShared::new(1, 10.0);
        ts.submit(rg(7, 100.0, 0.0, 0), 0.0);
        let rg = ts.cancel(7, 4.0).unwrap();
        assert_eq!(rg.gridlet.status, GridletStatus::Canceled);
        assert_eq!(rg.gridlet.cpu_time, 4.0); // 40 MI consumed / 10 MIPS
        assert_eq!(ts.in_exec(), 0);
        assert!(ts.cancel(7, 5.0).is_none());
    }

    #[test]
    fn drain_loses_everything() {
        let mut ts = TimeShared::new(2, 1.0);
        ts.submit(rg(0, 10.0, 0.0, 0), 0.0);
        ts.submit(rg(1, 10.0, 0.0, 1), 0.0);
        let all = ts.drain(3.0);
        assert_eq!(all.len(), 2);
        assert!(all.iter().all(|rg| rg.gridlet.status == GridletStatus::Lost));
        assert_eq!(ts.in_exec(), 0);
    }

    #[test]
    fn withheld_pes_reduce_capacity() {
        let mut ts = TimeShared::new(2, 1.0);
        ts.set_withheld_pes(1, 0.0);
        ts.submit(rg(0, 10.0, 0.0, 0), 0.0);
        ts.submit(rg(1, 10.0, 0.0, 1), 0.0);
        // One effective PE shared by two jobs → both at rate 0.5, done at 40.
        assert_eq!(ts.next_completion(0.0), Some(20.0));
    }

    #[test]
    fn empty_has_no_completion() {
        let mut ts = TimeShared::new(2, 1.0);
        assert_eq!(ts.next_completion(0.0), None);
        assert!(ts.collect(5.0).is_empty());
    }
}
