//! The GridSim entity toolkit (paper §3): resources (time- and space-shared),
//! Gridlets, the grid information service, network delays, statistics,
//! calendars, randomness, and advance reservations.

pub mod calendar;
pub mod characteristics;
pub mod gis;
pub mod gridlet;
pub mod machine;
pub mod messages;
pub mod network;
pub mod pe;
pub mod pool;
pub mod random;
pub mod res_gridlet;
pub mod reservation;
pub mod resource;
pub mod shutdown;
pub mod space_shared;
pub mod statistics;
pub mod tags;
pub mod time_shared;

pub use calendar::ResourceCalendar;
pub use characteristics::{AllocPolicy, ResourceCharacteristics, SpacePolicy};
pub use gis::GridInformationService;
pub use gridlet::{Gridlet, GridletStatus};
pub use machine::{Machine, MachineList};
pub use messages::{Msg, ResourceDynamics, ResourceInfo};
pub use network::BaudLink;
pub use pe::{Pe, PeList, PeStatus};
pub use random::GridSimRandom;
pub use res_gridlet::ResGridlet;
pub use resource::GridResource;
pub use shutdown::GridSimShutdown;
pub use statistics::{Accumulator, GridStatistics, StatRecord};
