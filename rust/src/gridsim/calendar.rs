//! `gridsim.ResourceCalendar` — local (non-grid) background load that varies
//! with the resource's time zone, hour of day, weekends and holidays
//! (paper §3.1/§3.6).
//!
//! The calendar maps a simulation time to a *load factor* in `[0, 1)`; the
//! resource scales its effective MIPS by `1 − load`. Simulation time units
//! are mapped to wall-clock via `units_per_hour` so that "weekend" has a
//! meaning; the paper leaves this mapping to the modeler.

/// Day-of-week index of Saturday (Monday = 0 … Sunday = 6).
pub const SATURDAY: usize = 5;
/// Day-of-week index of Sunday (Monday = 0 … Sunday = 6).
pub const SUNDAY: usize = 6;

/// Background-load calendar for one resource.
#[derive(Debug, Clone)]
pub struct ResourceCalendar {
    /// Time-zone offset in hours relative to simulation time zero.
    pub time_zone: f64,
    /// Load during local business hours (weekdays 9:00–17:00).
    pub peak_load: f64,
    /// Load outside business hours on weekdays.
    pub off_peak_load: f64,
    /// Load on weekends and holidays.
    pub holiday_load: f64,
    /// Days of week counted as weekend (Monday = 0).
    pub weekends: Vec<usize>,
    /// Holidays as day-of-year indices (0-based, 365-day year).
    pub holidays: Vec<usize>,
    /// Simulation time units per hour of calendar time.
    pub units_per_hour: f64,
}

impl ResourceCalendar {
    /// A calendar with no background load at all (the paper's single-user
    /// scheduling experiments set load factors to 0).
    pub fn no_load() -> ResourceCalendar {
        ResourceCalendar {
            time_zone: 0.0,
            peak_load: 0.0,
            off_peak_load: 0.0,
            holiday_load: 0.0,
            weekends: vec![SATURDAY, SUNDAY],
            holidays: vec![],
            units_per_hour: 1.0,
        }
    }

    /// Typical business-hours profile for a resource in `time_zone`.
    pub fn business(time_zone: f64, peak: f64, off_peak: f64, holiday: f64) -> ResourceCalendar {
        assert!((0.0..1.0).contains(&peak));
        assert!((0.0..1.0).contains(&off_peak));
        assert!((0.0..1.0).contains(&holiday));
        ResourceCalendar {
            time_zone,
            peak_load: peak,
            off_peak_load: off_peak,
            holiday_load: holiday,
            weekends: vec![SATURDAY, SUNDAY],
            holidays: vec![],
            units_per_hour: 1.0,
        }
    }

    /// Local hour-of-day (0..24) at simulation time `t`.
    pub fn local_hour(&self, t: f64) -> f64 {
        let hours = t / self.units_per_hour + self.time_zone;
        hours.rem_euclid(24.0)
    }

    /// Local day-of-week (Monday = 0) at simulation time `t`.
    pub fn local_day_of_week(&self, t: f64) -> usize {
        let hours = t / self.units_per_hour + self.time_zone;
        let days = (hours / 24.0).floor() as i64;
        days.rem_euclid(7) as usize
    }

    /// Local day-of-year (0..365) at simulation time `t`.
    pub fn local_day_of_year(&self, t: f64) -> usize {
        let hours = t / self.units_per_hour + self.time_zone;
        let days = (hours / 24.0).floor() as i64;
        days.rem_euclid(365) as usize
    }

    /// Background load factor in `[0, 1)` at simulation time `t`.
    pub fn load(&self, t: f64) -> f64 {
        let dow = self.local_day_of_week(t);
        let doy = self.local_day_of_year(t);
        if self.weekends.contains(&dow) || self.holidays.contains(&doy) {
            return self.holiday_load;
        }
        let hour = self.local_hour(t);
        if (9.0..17.0).contains(&hour) {
            self.peak_load
        } else {
            self.off_peak_load
        }
    }

    /// Effective MIPS multiplier at time `t` (`1 − load`).
    pub fn availability(&self, t: f64) -> f64 {
        1.0 - self.load(t)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn no_load_is_always_full() {
        let c = ResourceCalendar::no_load();
        for t in [0.0, 13.0, 1e6] {
            assert_eq!(c.load(t), 0.0);
            assert_eq!(c.availability(t), 1.0);
        }
    }

    #[test]
    fn peak_vs_off_peak() {
        let c = ResourceCalendar::business(0.0, 0.8, 0.2, 0.05);
        // t=0 is Monday 00:00 → off-peak.
        assert_eq!(c.load(0.0), 0.2);
        // Monday 10:00 → peak.
        assert_eq!(c.load(10.0), 0.8);
        // Monday 18:00 → off-peak.
        assert_eq!(c.load(18.0), 0.2);
    }

    #[test]
    fn weekend_low_load() {
        let c = ResourceCalendar::business(0.0, 0.8, 0.2, 0.05);
        // Day 5 (Saturday) 12:00 = hour 5*24+12 = 132.
        assert_eq!(c.local_day_of_week(132.0), SATURDAY);
        assert_eq!(c.load(132.0), 0.05);
    }

    #[test]
    fn time_zone_shifts_hours() {
        let c = ResourceCalendar::business(9.0, 0.8, 0.2, 0.05);
        // Sim time 1.0 → local hour 10 → peak (still Monday).
        assert_eq!(c.local_hour(1.0), 10.0);
        assert_eq!(c.load(1.0), 0.8);
    }

    #[test]
    fn holidays_override() {
        let mut c = ResourceCalendar::business(0.0, 0.8, 0.2, 0.05);
        c.holidays.push(0); // day zero is a holiday
        assert_eq!(c.load(10.0), 0.05);
        // Next day is a regular Tuesday.
        assert_eq!(c.load(24.0 + 10.0), 0.8);
    }

    #[test]
    fn units_per_hour_scaling() {
        let mut c = ResourceCalendar::business(0.0, 0.5, 0.1, 0.0);
        c.units_per_hour = 3600.0; // one unit = one second
        assert_eq!(c.local_hour(3600.0 * 10.0), 10.0);
        assert_eq!(c.load(3600.0 * 10.0), 0.5);
    }

    #[test]
    fn week_wraps() {
        let c = ResourceCalendar::no_load();
        assert_eq!(c.local_day_of_week(0.0), 0);
        assert_eq!(c.local_day_of_week(7.0 * 24.0), 0);
        assert_eq!(c.local_day_of_week(8.0 * 24.0), 1);
    }
}
