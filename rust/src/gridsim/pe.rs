//! `gridsim.PE` / `gridsim.PEList` — processing elements (paper §3.5/§3.6).

/// Allocation status of a PE.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PeStatus {
    /// Idle and allocatable.
    Free,
    /// Allocated to a Gridlet.
    Busy,
    /// Unavailable due to an injected failure.
    Failed,
}

/// A processing element with a MIPS (or SPEC-equivalent) rating.
#[derive(Debug, Clone)]
pub struct Pe {
    /// PE id, unique within its machine.
    pub id: usize,
    /// Processing rating in MIPS.
    pub mips: f64,
    /// Current allocation status.
    pub status: PeStatus,
}

impl Pe {
    /// A free PE; panics on a non-positive MIPS rating.
    pub fn new(id: usize, mips: f64) -> Pe {
        assert!(mips > 0.0, "PE MIPS rating must be positive");
        Pe { id, mips, status: PeStatus::Free }
    }
}

/// A list of PEs making up one machine.
#[derive(Debug, Clone, Default)]
pub struct PeList {
    pes: Vec<Pe>,
}

impl PeList {
    /// An empty PE list.
    pub fn new() -> PeList {
        PeList { pes: Vec::new() }
    }

    /// Uniform list constructor: `n` PEs at `mips` each.
    pub fn uniform(n: usize, mips: f64) -> PeList {
        let mut list = PeList::new();
        for i in 0..n {
            list.add(Pe::new(i, mips));
        }
        list
    }

    /// Append a PE.
    pub fn add(&mut self, pe: Pe) {
        self.pes.push(pe);
    }

    /// Number of PEs.
    pub fn len(&self) -> usize {
        self.pes.len()
    }

    /// `true` when the list holds no PEs.
    pub fn is_empty(&self) -> bool {
        self.pes.is_empty()
    }

    /// Iterate over the PEs in id order.
    pub fn iter(&self) -> impl Iterator<Item = &Pe> {
        self.pes.iter()
    }

    /// The `i`-th PE; panics when out of range.
    pub fn get(&self, i: usize) -> &Pe {
        &self.pes[i]
    }

    /// Mutable access to the `i`-th PE; panics when out of range.
    pub fn get_mut(&mut self, i: usize) -> &mut Pe {
        &mut self.pes[i]
    }

    /// Total MIPS across PEs.
    pub fn total_mips(&self) -> f64 {
        self.pes.iter().map(|p| p.mips).sum()
    }

    /// MIPS rating of the first PE (the paper assumes homogeneous PEs within
    /// a resource; `MIPSRatingOfOnePE()` in Fig 8).
    pub fn mips_of_one(&self) -> f64 {
        self.pes.first().map(|p| p.mips).unwrap_or(0.0)
    }

    /// Number of currently free PEs.
    pub fn free_count(&self) -> usize {
        self.pes.iter().filter(|p| p.status == PeStatus::Free).count()
    }

    /// Index of a free PE, if any.
    pub fn find_free(&self) -> Option<usize> {
        self.pes.iter().position(|p| p.status == PeStatus::Free)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_list() {
        let list = PeList::uniform(4, 377.0);
        assert_eq!(list.len(), 4);
        assert_eq!(list.total_mips(), 4.0 * 377.0);
        assert_eq!(list.mips_of_one(), 377.0);
        assert_eq!(list.free_count(), 4);
    }

    #[test]
    fn find_and_mark_busy() {
        let mut list = PeList::uniform(2, 100.0);
        let i = list.find_free().unwrap();
        list.get_mut(i).status = PeStatus::Busy;
        assert_eq!(list.free_count(), 1);
        let j = list.find_free().unwrap();
        assert_ne!(i, j);
        list.get_mut(j).status = PeStatus::Busy;
        assert_eq!(list.find_free(), None);
    }

    #[test]
    #[should_panic(expected = "positive")]
    fn zero_mips_rejected() {
        Pe::new(0, 0.0);
    }

    #[test]
    fn empty_list_mips() {
        let list = PeList::new();
        assert_eq!(list.mips_of_one(), 0.0);
        assert_eq!(list.total_mips(), 0.0);
        assert!(list.is_empty());
    }
}
