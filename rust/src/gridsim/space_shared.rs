//! Space-shared resource scheduling — the paper's Fig 10 event handler and
//! Fig 11 PE allocation: each Gridlet runs on dedicated PE(s); arrivals that
//! find no free PE wait in a submission queue ordered by the allocation
//! policy (FCFS, SJF, or EASY backfilling — §3.5).

use super::characteristics::SpacePolicy;
use super::gridlet::GridletStatus;
use super::res_gridlet::ResGridlet;
use super::resource::LocalScheduler;
use std::collections::VecDeque;

/// A running job: which machine, how many PEs, and its completion time.
#[derive(Debug)]
struct Running {
    rg: ResGridlet,
    machine: usize,
    pes: usize,
    finish: f64,
}

/// Space-shared (queueing system) scheduler state.
#[derive(Debug)]
pub struct SpaceShared {
    /// Free PEs per machine.
    free: Vec<usize>,
    /// PEs per machine (capacity).
    capacity: Vec<usize>,
    mips_per_pe: f64,
    policy: SpacePolicy,
    availability: f64,
    withheld: usize,
    exec: Vec<Running>,
    queue: VecDeque<ResGridlet>,
}

impl SpaceShared {
    /// A space-shared scheduler over machines with `machine_pes[i]` PEs
    /// each, all rated `mips_per_pe`, ordering its queue by `policy`.
    pub fn new(machine_pes: &[usize], mips_per_pe: f64, policy: SpacePolicy) -> SpaceShared {
        assert!(!machine_pes.is_empty());
        assert!(mips_per_pe > 0.0);
        SpaceShared {
            free: machine_pes.to_vec(),
            capacity: machine_pes.to_vec(),
            mips_per_pe,
            policy,
            availability: 1.0,
            withheld: 0,
            exec: Vec::new(),
            queue: VecDeque::new(),
        }
    }

    /// Convenience constructor: a cluster of `n` uniprocessor machines.
    pub fn cluster(n: usize, mips_per_pe: f64, policy: SpacePolicy) -> SpaceShared {
        SpaceShared::new(&vec![1; n], mips_per_pe, policy)
    }

    fn total_free(&self) -> usize {
        self.free.iter().sum::<usize>().saturating_sub(self.withheld)
    }

    /// Estimated runtime of a job on this resource.
    fn runtime(&self, rg: &ResGridlet) -> f64 {
        rg.remaining_mi / (self.mips_per_pe * self.availability)
    }

    /// Find a machine with `pes` free PEs (first fit — Fig 11 step 1:
    /// "identify a suitable machine with free PE").
    fn find_machine(&self, pes: usize) -> Option<usize> {
        self.free.iter().position(|&f| f >= pes)
    }

    /// Start a job now (Fig 11): allocate PEs, mark busy, forecast finish.
    fn start(&mut self, mut rg: ResGridlet, machine: usize, now: f64) {
        let pes = rg.gridlet.num_pe;
        debug_assert!(self.free[machine] >= pes);
        self.free[machine] -= pes;
        rg.start = now;
        rg.gridlet.status = GridletStatus::InExec;
        rg.machine = Some(machine);
        let finish = now + self.runtime(&rg);
        self.exec.push(Running { rg, machine, pes, finish });
    }

    /// Can a job requiring `pes` PEs start right now (respecting the
    /// withheld-PE pool)?
    fn can_start(&self, pes: usize) -> Option<usize> {
        if self.total_free() < pes {
            return None;
        }
        self.find_machine(pes)
    }

    /// Pull queued jobs onto free PEs according to the policy.
    fn dispatch_queue(&mut self, now: f64) {
        match self.policy {
            SpacePolicy::Fcfs => {
                // Strict FCFS: stop at the first job that does not fit.
                while let Some(head) = self.queue.front() {
                    match self.can_start(head.gridlet.num_pe) {
                        Some(m) => {
                            let rg = self.queue.pop_front().unwrap();
                            self.start(rg, m, now);
                        }
                        None => break,
                    }
                }
            }
            SpacePolicy::Sjf => {
                // Repeatedly start the shortest queued job that fits.
                loop {
                    let mut best: Option<(usize, usize)> = None; // (queue idx, machine)
                    for (i, rg) in self.queue.iter().enumerate() {
                        if let Some(m) = self.can_start(rg.gridlet.num_pe) {
                            let better = match best {
                                None => true,
                                Some((bi, _)) => {
                                    rg.remaining_mi < self.queue[bi].remaining_mi
                                }
                            };
                            if better {
                                best = Some((i, m));
                            }
                        }
                    }
                    match best {
                        Some((i, m)) => {
                            let rg = self.queue.remove(i).unwrap();
                            self.start(rg, m, now);
                        }
                        None => break,
                    }
                }
            }
            SpacePolicy::BackfillEasy => self.dispatch_backfill(now),
        }
    }

    /// EASY backfilling: start the head if possible; otherwise compute the
    /// head's *shadow time* (earliest time enough PEs free up) and let later
    /// jobs run now iff they finish by the shadow time or fit into the PEs
    /// the head will not need.
    fn dispatch_backfill(&mut self, now: f64) {
        loop {
            let Some(head) = self.queue.front() else { return };
            if let Some(m) = self.can_start(head.gridlet.num_pe) {
                let rg = self.queue.pop_front().unwrap();
                self.start(rg, m, now);
                continue;
            }
            break;
        }
        let Some(head) = self.queue.front() else { return };
        let head_pes = head.gridlet.num_pe;
        // Shadow time: walk running jobs by finish time until enough PEs
        // would be free for the head.
        let mut finishes: Vec<(f64, usize)> =
            self.exec.iter().map(|r| (r.finish, r.pes)).collect();
        finishes.sort_by(|a, b| a.0.total_cmp(&b.0));
        let mut free = self.total_free();
        let mut shadow = f64::INFINITY;
        for (t, pes) in finishes {
            free += pes;
            if free >= head_pes {
                shadow = t;
                break;
            }
        }
        // PEs the head leaves over at shadow time.
        let spare = free.saturating_sub(head_pes);
        // Backfill candidates: everything after the head, in order.
        let mut i = 1;
        while i < self.queue.len() {
            let rg = &self.queue[i];
            let pes = rg.gridlet.num_pe;
            let fits_now = self.can_start(pes);
            let finishes_in_time = now + self.runtime(rg) <= shadow + 1e-12;
            let fits_spare = pes <= spare;
            if let (Some(m), true) = (fits_now, finishes_in_time || fits_spare) {
                let rg = self.queue.remove(i).unwrap();
                self.start(rg, m, now);
                // Restart scan: free counts changed.
                i = 1;
            } else {
                i += 1;
            }
        }
    }

    /// Cancel the first queued or running job matching `pred` (shared by
    /// both cancel entry points).
    fn cancel_matching(
        &mut self,
        pred: impl Fn(&ResGridlet) -> bool,
        now: f64,
    ) -> Option<ResGridlet> {
        // Queued jobs cancel for free.
        if let Some(i) = self.queue.iter().position(|rg| pred(rg)) {
            let mut rg = self.queue.remove(i).unwrap();
            rg.gridlet.status = GridletStatus::Canceled;
            rg.gridlet.finish_time = now;
            rg.gridlet.cpu_time = 0.0;
            return Some(rg);
        }
        // Running jobs free their PEs and are charged for consumed time.
        let i = self.exec.iter().position(|r| pred(&r.rg))?;
        let Running { mut rg, machine, pes, .. } = self.exec.remove(i);
        self.free[machine] += pes;
        let ran = (now - rg.start).max(0.0);
        rg.consume(ran * self.mips_per_pe * self.availability);
        rg.gridlet.status = GridletStatus::Canceled;
        rg.gridlet.finish_time = now;
        rg.gridlet.cpu_time = ran * pes as f64;
        self.dispatch_queue(now);
        Some(rg)
    }

    /// Test hook: ids currently executing.
    pub fn exec_ids(&self) -> Vec<usize> {
        self.exec.iter().map(|r| r.rg.gridlet.id).collect()
    }

    /// Test hook: ids currently queued, in queue order.
    pub fn queue_ids(&self) -> Vec<usize> {
        self.queue.iter().map(|rg| rg.gridlet.id).collect()
    }
}

impl LocalScheduler for SpaceShared {
    fn set_availability(&mut self, factor: f64, _now: f64) {
        // Applies to jobs started after the change (running jobs keep their
        // forecast completion — dedicated PEs are not re-shared).
        self.availability = factor.clamp(0.0, 1.0).max(1e-9);
    }

    fn set_withheld_pes(&mut self, pes: usize, now: f64) {
        self.withheld = pes;
        // Withholding never preempts running work; it only gates dispatch.
        let _ = now;
    }

    fn submit(&mut self, mut rg: ResGridlet, now: f64) {
        assert!(
            rg.gridlet.num_pe <= self.capacity.iter().copied().max().unwrap_or(0),
            "gridlet {} needs {} PEs, larger than any machine",
            rg.gridlet.id,
            rg.gridlet.num_pe
        );
        // Fig 10 step 2: start immediately if a PE is free, else queue.
        rg.gridlet.status = GridletStatus::Queued;
        self.queue.push_back(rg);
        self.dispatch_queue(now);
    }

    fn collect(&mut self, now: f64) -> Vec<ResGridlet> {
        let mut done = Vec::new();
        let mut i = 0;
        while i < self.exec.len() {
            if self.exec[i].finish <= now + 1e-9 {
                let Running { mut rg, machine, pes, finish } = self.exec.remove(i);
                self.free[machine] += pes;
                rg.remaining_mi = 0.0;
                rg.gridlet.status = GridletStatus::Success;
                rg.gridlet.finish_time = finish;
                rg.gridlet.cpu_time =
                    (finish - rg.start) * pes as f64;
                done.push(rg);
            } else {
                i += 1;
            }
        }
        if !done.is_empty() {
            // Fig 10 step 3: a completion frees PEs; pick waiting jobs.
            self.dispatch_queue(now);
        }
        done
    }

    fn next_completion(&mut self, _now: f64) -> Option<f64> {
        self.exec.iter().map(|r| r.finish).min_by(|a, b| a.total_cmp(b))
    }

    fn in_exec(&self) -> usize {
        self.exec.len()
    }

    fn queued(&self) -> usize {
        self.queue.len()
    }

    fn cancel(&mut self, gridlet_id: usize, now: f64) -> Option<ResGridlet> {
        self.cancel_matching(|rg| rg.gridlet.id == gridlet_id, now)
    }

    fn cancel_owned(
        &mut self,
        owner: crate::des::EntityId,
        gridlet_id: usize,
        now: f64,
    ) -> Option<ResGridlet> {
        self.cancel_matching(
            |rg| rg.gridlet.owner == owner && rg.gridlet.id == gridlet_id,
            now,
        )
    }

    fn status_of(&self, gridlet_id: usize) -> Option<GridletStatus> {
        if let Some(r) = self.exec.iter().find(|r| r.rg.gridlet.id == gridlet_id) {
            return Some(r.rg.gridlet.status);
        }
        self.queue
            .iter()
            .find(|rg| rg.gridlet.id == gridlet_id)
            .map(|rg| rg.gridlet.status)
    }

    fn drain(&mut self, now: f64) -> Vec<ResGridlet> {
        let mut all = Vec::new();
        for Running { mut rg, machine, pes, .. } in self.exec.drain(..) {
            self.free[machine] += pes;
            rg.gridlet.status = GridletStatus::Lost;
            rg.gridlet.finish_time = now;
            all.push(rg);
        }
        for mut rg in self.queue.drain(..) {
            rg.gridlet.status = GridletStatus::Lost;
            rg.gridlet.finish_time = now;
            all.push(rg);
        }
        all
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gridsim::gridlet::Gridlet;

    fn rg(id: usize, mi: f64, now: f64, rank: u64) -> ResGridlet {
        ResGridlet::new(Gridlet::new(id, mi, 0, 0), now, rank)
    }

    fn rg_pes(id: usize, mi: f64, pes: usize) -> ResGridlet {
        ResGridlet::new(Gridlet::new(id, mi, 0, 0).with_pes(pes), 0.0, id as u64)
    }

    /// The paper's Table 1 / Fig 12 scenario.
    #[test]
    fn table1_space_shared_exact() {
        let mut ss = SpaceShared::new(&[2], 1.0, SpacePolicy::Fcfs);
        // t=0: G1 → PE1, finish 10.
        ss.submit(rg(1, 10.0, 0.0, 0), 0.0);
        assert_eq!(ss.next_completion(0.0), Some(10.0));
        // t=4: G2 → PE2, finish 12.5.
        ss.submit(rg(2, 8.5, 4.0, 1), 4.0);
        assert_eq!(ss.in_exec(), 2);
        // t=7: G3 queued (no free PE).
        ss.submit(rg(3, 9.5, 7.0, 2), 7.0);
        assert_eq!(ss.queued(), 1);
        // t=10: G1 completes; G3 starts → finish 19.5.
        let done = ss.collect(10.0);
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].gridlet.id, 1);
        assert_eq!(done[0].gridlet.finish_time, 10.0);
        assert_eq!(ss.queued(), 0);
        assert_eq!(ss.in_exec(), 2);
        // t=12.5: G2 completes (elapsed 8.5).
        assert_eq!(ss.next_completion(10.0), Some(12.5));
        let done = ss.collect(12.5);
        assert_eq!(done[0].gridlet.id, 2);
        assert_eq!(done[0].gridlet.elapsed(), 8.5);
        // t=19.5: G3 completes (elapsed 12.5 — Table 1).
        assert_eq!(ss.next_completion(12.5), Some(19.5));
        let done = ss.collect(19.5);
        assert_eq!(done[0].gridlet.id, 3);
        assert_eq!(done[0].gridlet.elapsed(), 12.5);
    }

    #[test]
    fn fcfs_does_not_reorder() {
        let mut ss = SpaceShared::new(&[1], 1.0, SpacePolicy::Fcfs);
        ss.submit(rg(0, 10.0, 0.0, 0), 0.0);
        ss.submit(rg(1, 100.0, 0.0, 1), 0.0); // long job queued first
        ss.submit(rg(2, 1.0, 0.0, 2), 0.0); // short job queued second
        let done = ss.collect(10.0);
        assert_eq!(done[0].gridlet.id, 0);
        // FCFS starts the long job even though a shorter one waits.
        assert_eq!(ss.exec_ids(), vec![1]);
        assert_eq!(ss.queue_ids(), vec![2]);
    }

    #[test]
    fn sjf_picks_shortest() {
        let mut ss = SpaceShared::new(&[1], 1.0, SpacePolicy::Sjf);
        ss.submit(rg(0, 10.0, 0.0, 0), 0.0);
        ss.submit(rg(1, 100.0, 0.0, 1), 0.0);
        ss.submit(rg(2, 1.0, 0.0, 2), 0.0);
        ss.collect(10.0);
        // SJF runs the 1-MI job before the 100-MI job.
        assert_eq!(ss.exec_ids(), vec![2]);
    }

    #[test]
    fn backfill_jumps_small_jobs() {
        // 2 PEs. Running: J0 uses 2 PEs until t=10. Queue: J1 needs 2 PEs
        // (head, must wait until 10), J2 needs 1 PE and runs 5 units.
        // EASY: J2 cannot start (0 free). After J0 finishes, J1 starts.
        let mut ss = SpaceShared::new(&[2], 1.0, SpacePolicy::BackfillEasy);
        ss.submit(rg_pes(0, 10.0, 2), 0.0);
        ss.submit(rg_pes(1, 10.0, 2), 0.0);
        ss.submit(rg_pes(2, 5.0, 1), 0.0);
        assert_eq!(ss.exec_ids(), vec![0]);
        assert_eq!(ss.queue_ids(), vec![1, 2]);

        // Now with one PE free: running J0 uses 1 PE until 10; head J1 needs
        // 2 PEs → shadow = 10. J2 (1 PE, 5 units, finishes at 5 ≤ 10)
        // backfills immediately.
        let mut ss = SpaceShared::new(&[2], 1.0, SpacePolicy::BackfillEasy);
        ss.submit(rg_pes(0, 10.0, 1), 0.0);
        ss.submit(rg_pes(1, 10.0, 2), 0.0);
        ss.submit(rg_pes(2, 5.0, 1), 0.0);
        assert_eq!(ss.exec_ids(), vec![0, 2], "J2 should backfill");
        assert_eq!(ss.queue_ids(), vec![1]);
    }

    #[test]
    fn backfill_refuses_delaying_head() {
        // J0 runs 1 PE until 10; head J1 needs 2 PEs (shadow 10).
        // J2 needs 1 PE for 20 units → would finish at 20 > shadow and
        // spare = (free at shadow 2 − head 2) = 0 → must NOT backfill.
        let mut ss = SpaceShared::new(&[2], 1.0, SpacePolicy::BackfillEasy);
        ss.submit(rg_pes(0, 10.0, 1), 0.0);
        ss.submit(rg_pes(1, 10.0, 2), 0.0);
        ss.submit(rg_pes(2, 20.0, 1), 0.0);
        assert_eq!(ss.exec_ids(), vec![0]);
        assert_eq!(ss.queue_ids(), vec![1, 2]);
    }

    #[test]
    fn multi_pe_needs_one_machine() {
        // Two machines × 2 PEs: a 2-PE job fits, even with 1 PE busy on m0.
        let mut ss = SpaceShared::new(&[2, 2], 1.0, SpacePolicy::Fcfs);
        ss.submit(rg_pes(0, 10.0, 1), 0.0);
        ss.submit(rg_pes(1, 10.0, 2), 0.0);
        assert_eq!(ss.in_exec(), 2);
        // A 3-PE job can never fit a 2-PE machine.
    }

    #[test]
    #[should_panic(expected = "larger than any machine")]
    fn oversized_job_rejected() {
        let mut ss = SpaceShared::new(&[2, 2], 1.0, SpacePolicy::Fcfs);
        ss.submit(rg_pes(0, 10.0, 3), 0.0);
    }

    #[test]
    fn cpu_time_counts_pes() {
        let mut ss = SpaceShared::new(&[2], 2.0, SpacePolicy::Fcfs);
        ss.submit(rg_pes(0, 10.0, 2), 0.0);
        let done = ss.collect(5.0);
        // runtime = 10/2 = 5; cpu_time = 5 × 2 PEs = 10 PE-units.
        assert_eq!(done[0].gridlet.finish_time, 5.0);
        assert_eq!(done[0].gridlet.cpu_time, 10.0);
    }

    #[test]
    fn cancel_queued_is_free() {
        let mut ss = SpaceShared::new(&[1], 1.0, SpacePolicy::Fcfs);
        ss.submit(rg(0, 10.0, 0.0, 0), 0.0);
        ss.submit(rg(1, 10.0, 0.0, 1), 0.0);
        let c = ss.cancel(1, 3.0).unwrap();
        assert_eq!(c.gridlet.status, GridletStatus::Canceled);
        assert_eq!(c.gridlet.cpu_time, 0.0);
    }

    #[test]
    fn cancel_running_frees_pe_and_dispatches() {
        let mut ss = SpaceShared::new(&[1], 1.0, SpacePolicy::Fcfs);
        ss.submit(rg(0, 10.0, 0.0, 0), 0.0);
        ss.submit(rg(1, 5.0, 0.0, 1), 0.0);
        let c = ss.cancel(0, 4.0).unwrap();
        assert_eq!(c.gridlet.cpu_time, 4.0);
        assert_eq!(c.remaining_mi, 6.0);
        // The queued job starts immediately.
        assert_eq!(ss.exec_ids(), vec![1]);
    }

    #[test]
    fn drain_flushes_exec_and_queue() {
        let mut ss = SpaceShared::new(&[1], 1.0, SpacePolicy::Fcfs);
        ss.submit(rg(0, 10.0, 0.0, 0), 0.0);
        ss.submit(rg(1, 10.0, 0.0, 1), 0.0);
        let all = ss.drain(2.0);
        assert_eq!(all.len(), 2);
        assert!(all.iter().all(|r| r.gridlet.status == GridletStatus::Lost));
        assert_eq!(ss.total_free(), 1);
    }

    #[test]
    fn withheld_gates_dispatch() {
        let mut ss = SpaceShared::new(&[2], 1.0, SpacePolicy::Fcfs);
        ss.set_withheld_pes(1, 0.0);
        ss.submit(rg(0, 10.0, 0.0, 0), 0.0);
        ss.submit(rg(1, 10.0, 0.0, 1), 0.0);
        assert_eq!(ss.in_exec(), 1);
        assert_eq!(ss.queued(), 1);
        ss.set_withheld_pes(0, 1.0);
        ss.dispatch_queue(1.0);
        assert_eq!(ss.in_exec(), 2);
    }

    #[test]
    fn availability_slows_new_jobs() {
        let mut ss = SpaceShared::new(&[1], 10.0, SpacePolicy::Fcfs);
        ss.set_availability(0.5, 0.0);
        ss.submit(rg(0, 100.0, 0.0, 0), 0.0);
        assert_eq!(ss.next_completion(0.0), Some(20.0));
    }
}
