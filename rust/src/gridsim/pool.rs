//! Thread-local recycling pool for boxed [`Gridlet`] payloads.
//!
//! Every hop of the submit → execute → return round trip moves a Gridlet
//! inside a `Msg::Gridlet(Box<Gridlet>)` event payload. Without pooling each
//! hop costs an allocator round trip (a `Box::new` at the sender and a drop
//! at the receiver), which dominates the event path at million-job scale.
//! This pool keeps a small per-thread free list of empty boxes: [`boxed`]
//! reuses one instead of allocating, and [`unbox`] returns the box to the
//! list instead of freeing it.
//!
//! Rules (also documented in `docs/ARCHITECTURE.md`):
//!
//! - A pooled box's previous contents are always fully overwritten by
//!   [`boxed`] before reuse, so pooling is invisible to simulation results —
//!   determinism does not depend on pool state.
//! - The pool is `thread_local!`, so sweep workers each recycle their own
//!   boxes; nothing is shared or locked across threads.
//! - The free list is capped ([`POOL_CAP`]) so a burst of in-flight Gridlets
//!   cannot pin memory forever; overflow boxes are simply dropped.

use super::gridlet::{Gridlet, GridletStatus};
use std::cell::RefCell;

/// Maximum number of idle boxes kept per thread. Beyond this, `unbox` frees
/// the box normally. 256 covers the paper's experiments (≤ 200 in-flight
/// Gridlets per user round) without holding more than ~32 KiB per worker.
const POOL_CAP: usize = 256;

thread_local! {
    static POOL: RefCell<Vec<Box<Gridlet>>> = const { RefCell::new(Vec::new()) };
}

/// An inert Gridlet used to displace real contents in [`unbox`]. Built as a
/// struct literal because `Gridlet::new` (correctly) rejects zero-length
/// jobs, and this placeholder is never observed by simulation code.
fn placeholder() -> Gridlet {
    Gridlet {
        id: 0,
        owner: 0,
        length_mi: 0.0,
        num_pe: 1,
        input_bytes: 0,
        output_bytes: 0,
        status: GridletStatus::Created,
        arrival_time: 0.0,
        start_time: 0.0,
        finish_time: 0.0,
        cpu_time: 0.0,
        cost: 0.0,
        resource: None,
    }
}

/// Box a Gridlet, reusing a pooled allocation when one is available.
/// The returned box's contents are exactly `g` regardless of pool state.
pub fn boxed(g: Gridlet) -> Box<Gridlet> {
    POOL.with(|pool| match pool.borrow_mut().pop() {
        Some(mut b) => {
            *b = g;
            b
        }
        None => Box::new(g),
    })
}

/// Take the Gridlet out of a box and recycle the allocation into the pool
/// (unless the pool is at [`POOL_CAP`], in which case the box is freed).
pub fn unbox(mut b: Box<Gridlet>) -> Gridlet {
    let g = std::mem::replace(&mut *b, placeholder());
    POOL.with(|pool| {
        let mut pool = pool.borrow_mut();
        if pool.len() < POOL_CAP {
            pool.push(b);
        }
    });
    g
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trip_preserves_contents() {
        let mut g = Gridlet::new(7, 420.0, 100, 50);
        g.owner = 3;
        g.status = GridletStatus::InExec;
        let expect = g.clone();
        let b = boxed(g);
        let back = unbox(b);
        assert_eq!(back.id, expect.id);
        assert_eq!(back.owner, expect.owner);
        assert_eq!(back.length_mi, expect.length_mi);
        assert_eq!(back.status, expect.status);
    }

    #[test]
    fn allocation_is_reused() {
        // Drain whatever earlier tests left behind so the reuse check below
        // observes this test's own box coming back.
        POOL.with(|p| p.borrow_mut().clear());
        let b = boxed(Gridlet::new(1, 1.0, 0, 0));
        let addr = &*b as *const Gridlet as usize;
        let _ = unbox(b);
        let b2 = boxed(Gridlet::new(2, 2.0, 0, 0));
        assert_eq!(&*b2 as *const Gridlet as usize, addr, "box recycled");
        assert_eq!(b2.id, 2, "contents fully overwritten");
    }

    #[test]
    fn pool_is_capped() {
        POOL.with(|p| p.borrow_mut().clear());
        let boxes: Vec<_> = (0..POOL_CAP + 10).map(|i| boxed(Gridlet::new(i, 1.0, 0, 0))).collect();
        for b in boxes {
            let _ = unbox(b);
        }
        POOL.with(|p| assert_eq!(p.borrow().len(), POOL_CAP));
    }
}
