//! `gridsim.Gridlet` — the unit of work (paper §3.3).
//!
//! A Gridlet packages everything about one job: processing length in MI
//! (million instructions, normalized to a SPEC/MIPS-rated standard PE),
//! input/output file sizes (which determine network staging delays), the
//! originator to return the result to, and — as it moves through the system —
//! its execution record (arrival/start/finish times, consumed CPU time,
//! accrued cost).

use crate::des::EntityId;

/// Lifecycle state of a Gridlet.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GridletStatus {
    /// Created by the user, not yet dispatched.
    Created,
    /// Sent to a resource, waiting for a free PE (space-shared queue).
    Queued,
    /// Executing on a resource.
    InExec,
    /// Finished successfully and returned to the originator.
    Success,
    /// Cancelled by the broker (deadline/budget exhausted or rebalancing).
    Canceled,
    /// Rejected by a resource (e.g. submitted while the resource was down).
    Failed,
    /// In flight on a resource when it failed: the work is gone and the
    /// broker's resubmission policy decides whether to retry or abandon.
    Lost,
    /// Evicted from a spot tier because the dynamic price crossed the
    /// user's bid. Unlike [`GridletStatus::Lost`], the partial work is
    /// charged (at the rate actually paid); the resubmission policy then
    /// decides whether the job retries on the on-demand tier.
    Preempted,
}

/// The job package.
#[derive(Debug, Clone)]
pub struct Gridlet {
    /// User-scoped job id.
    pub id: usize,
    /// Entity the processed Gridlet is returned to (broker or user).
    pub owner: EntityId,
    /// Processing requirement in MI, relative to the standard PE
    /// (`GridSimStandardPE`, 100 MIPS in the paper's experiments).
    pub length_mi: f64,
    /// Number of PEs required simultaneously (1 for task-farming jobs;
    /// >1 exercises space-shared backfilling).
    pub num_pe: usize,
    /// Input file size in bytes (staged user -> resource).
    pub input_bytes: u64,
    /// Output file size in bytes (staged resource -> user).
    pub output_bytes: u64,
    /// Lifecycle state.
    pub status: GridletStatus,
    /// Simulation time the Gridlet arrived at the resource.
    pub arrival_time: f64,
    /// Simulation time execution began.
    pub start_time: f64,
    /// Simulation time execution finished.
    pub finish_time: f64,
    /// PE time consumed (CPU time; equals `length_mi / mips` of the PE that
    /// ran it — for time-shared resources wall-clock can be much larger).
    pub cpu_time: f64,
    /// Cost charged for processing (filled in by the broker:
    /// `price/PE-time-unit × cpu_time`).
    pub cost: f64,
    /// Resource that processed (or is processing) the Gridlet.
    pub resource: Option<EntityId>,
    /// Price per PE-time actually paid: stamped by a market-carrying
    /// resource at return (the time-averaged dynamic price over the job's
    /// residency, spot-discounted for bid-carrying jobs). `NaN` when no
    /// market priced the run — the broker then falls back to the
    /// resource's static price.
    pub paid_rate: f64,
    /// The user's spot bid in G$ per PE per time unit, stamped at dispatch
    /// when the job rents a spot tier. `NaN` marks an on-demand job (never
    /// preempted, pays the undiscounted price).
    pub max_spot_price: f64,
}

impl Gridlet {
    /// Create a fresh Gridlet. `owner` is patched by the broker before
    /// dispatch (the paper sets the owner id so resources know where to
    /// return results).
    pub fn new(id: usize, length_mi: f64, input_bytes: u64, output_bytes: u64) -> Gridlet {
        assert!(length_mi > 0.0, "gridlet length must be positive");
        Gridlet {
            id,
            owner: 0,
            length_mi,
            num_pe: 1,
            input_bytes,
            output_bytes,
            status: GridletStatus::Created,
            arrival_time: 0.0,
            start_time: 0.0,
            finish_time: 0.0,
            cpu_time: 0.0,
            cost: 0.0,
            resource: None,
            paid_rate: f64::NAN,
            max_spot_price: f64::NAN,
        }
    }

    /// Builder-style PE requirement (multi-PE jobs for space-shared tests).
    pub fn with_pes(mut self, num_pe: usize) -> Gridlet {
        assert!(num_pe >= 1);
        self.num_pe = num_pe;
        self
    }

    /// Wall-clock (elapsed) time at the resource: `finish − arrival`
    /// (Table 1's "Elapsed Time" column).
    pub fn elapsed(&self) -> f64 {
        self.finish_time - self.arrival_time
    }

    /// True when the Gridlet reached a terminal state.
    pub fn is_terminal(&self) -> bool {
        matches!(
            self.status,
            GridletStatus::Success
                | GridletStatus::Canceled
                | GridletStatus::Failed
                | GridletStatus::Lost
                | GridletStatus::Preempted
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn new_defaults() {
        let g = Gridlet::new(3, 10_000.0, 512, 128);
        assert_eq!(g.id, 3);
        assert_eq!(g.status, GridletStatus::Created);
        assert_eq!(g.num_pe, 1);
        assert!(!g.is_terminal());
    }

    #[test]
    fn elapsed_is_finish_minus_arrival() {
        let mut g = Gridlet::new(0, 10.0, 0, 0);
        g.arrival_time = 4.0;
        g.finish_time = 14.0;
        assert_eq!(g.elapsed(), 10.0);
    }

    #[test]
    fn terminal_states() {
        let mut g = Gridlet::new(0, 1.0, 0, 0);
        for (st, terminal) in [
            (GridletStatus::Created, false),
            (GridletStatus::Queued, false),
            (GridletStatus::InExec, false),
            (GridletStatus::Success, true),
            (GridletStatus::Canceled, true),
            (GridletStatus::Failed, true),
            (GridletStatus::Lost, true),
            (GridletStatus::Preempted, true),
        ] {
            g.status = st;
            assert_eq!(g.is_terminal(), terminal, "{st:?}");
        }
    }

    #[test]
    #[should_panic(expected = "positive")]
    fn zero_length_rejected() {
        Gridlet::new(0, 0.0, 0, 0);
    }

    #[test]
    fn with_pes() {
        let g = Gridlet::new(0, 1.0, 0, 0).with_pes(4);
        assert_eq!(g.num_pe, 4);
    }
}
