//! Protocol tags — the `gridsim.GridSimTags` constants (paper Fig 14).
//!
//! Tags select the service requested when an event is delivered; the values
//! mirror the paper's published constants where they exist and extend them
//! for internal bookkeeping.

/// Deliver with no delay.
pub const SCHEDULE_NOW: f64 = 0.0;

/// End-of-simulation control message (user -> shutdown entity).
pub const END_OF_SIMULATION: i64 = -1;

/// Ignorable event.
pub const INSIGNIFICANT: i64 = 0;
/// User <-> Broker: run an experiment.
pub const EXPERIMENT: i64 = 1;
/// Resource -> GIS: register.
pub const REGISTER_RESOURCE: i64 = 2;
/// GIS <-> Broker: resource discovery.
pub const RESOURCE_LIST: i64 = 3;
/// Broker <-> Resource: static characteristics query/reply.
pub const RESOURCE_CHARACTERISTICS: i64 = 4;
/// Broker <-> Resource: dynamic state (load) query/reply.
pub const RESOURCE_DYNAMICS: i64 = 5;
/// Broker -> Resource: submit a Gridlet for execution.
pub const GRIDLET_SUBMIT: i64 = 6;
/// Resource -> Broker: return a processed Gridlet.
pub const GRIDLET_RETURN: i64 = 7;
/// Broker <-> Resource: query the status of a submitted Gridlet.
pub const GRIDLET_STATUS: i64 = 8;
/// Entity -> GridStatistics: record a measurement.
pub const RECORD_STATISTICS: i64 = 9;
/// Entity <- GridStatistics: recorded series reply.
pub const RETURN_STAT_LIST: i64 = 10;
/// Entity <- GridStatistics: accumulator reply by category.
pub const RETURN_ACC_STATISTICS_BY_CATEGORY: i64 = 11;

/// Broker -> Resource: cancel a previously submitted Gridlet (needed by the
/// DBC schedule advisor when it moves jobs back to the unassigned queue).
pub const GRIDLET_CANCEL: i64 = 12;
/// Resource -> Broker: reply to a cancel request.
pub const GRIDLET_CANCEL_REPLY: i64 = 13;
/// Broker -> Resource: advance-reservation request (paper §3.1 / future work).
pub const RESERVATION_REQUEST: i64 = 14;
/// Resource -> Broker: advance-reservation reply.
pub const RESERVATION_REPLY: i64 = 15;
/// User -> Broker: one more Gridlet of an already-submitted experiment
/// (online application models — the job arrives *during* the run and the
/// broker extends its plan mid-flight).
pub const GRIDLET_ARRIVAL: i64 = 16;
/// Resource -> subscribed brokers: the resource's dynamic price changed
/// (market layer). Only emitted by resources carrying a market — scenarios
/// without a `"pricing"`/`"spot"` block never see this tag.
pub const PRICE_UPDATE: i64 = 17;
/// Broker -> User: one Gridlet of a precedence-gated (DAG) workflow
/// completed successfully; the user releases any children whose parents
/// are now all complete (workflow layer). Only sent when the experiment
/// asks for completion notices — task-farm scenarios never see this tag.
pub const GRIDLET_COMPLETED: i64 = 18;
/// Broker -> User: a Gridlet of a precedence-gated workflow was abandoned
/// (resubmission policy gave up); the user prunes every withheld
/// descendant — they can never become eligible — and reports the count
/// back via [`DAG_CASCADE`].
pub const GRIDLET_ABANDONED: i64 = 19;
/// User -> Broker: the number of withheld workflow jobs pruned after a
/// [`GRIDLET_ABANDONED`] notice, so broker termination accounting covers
/// jobs that will now never arrive.
pub const DAG_CASCADE: i64 = 20;

/// Internal: resource forecast interrupt (Gridlet completion tick).
pub const RESOURCE_TICK: i64 = 100;
/// Internal: broker scheduling-loop tick.
pub const BROKER_TICK: i64 = 101;
/// Internal: user activity tick (job creation).
pub const USER_TICK: i64 = 102;
/// User -> Broker / Broker -> User: experiment completion handoff.
pub const EXPERIMENT_DONE: i64 = 103;
/// Resource failure injection (fault-tolerance testing).
pub const RESOURCE_FAIL: i64 = 104;
/// Resource recovery after failure.
pub const RESOURCE_RECOVER: i64 = 105;
/// Internal: fault-injector self-tick (next failure/repair transition of
/// one resource's failure–repair process).
pub const FAULT_TICK: i64 = 106;

/// Default baud rate (bits per simulated second) — paper Fig 14.
pub const DEFAULT_BAUD_RATE: f64 = 9600.0;
