//! Network model — the paper's `gridsim.Input`/`gridsim.Output` entities
//! (§3.2.2, Fig 4) reduced to their observable semantics: every message
//! between networked entities is delayed by `latency + bits / baud_rate`.
//!
//! The paper gives each entity an I/O port pair with a baud rate
//! (`DEFAULT_BAUD_RATE = 9600`); the effective rate of a transfer is bounded
//! by the slower endpoint. Pairwise latency can be layered on top to model
//! wide-area links between time zones.

use super::tags;
use crate::des::entity::LinkModel;
use crate::des::EntityId;
use std::collections::HashMap;

/// Baud-rate + latency link model.
#[derive(Debug, Clone)]
pub struct BaudLink {
    /// Per-entity baud rate (bits per simulation time unit); entities not
    /// present use the default.
    rates: HashMap<EntityId, f64>,
    default_rate: f64,
    /// Pairwise one-way latency overrides (symmetric).
    latency: HashMap<(EntityId, EntityId), f64>,
    default_latency: f64,
}

impl Default for BaudLink {
    fn default() -> Self {
        Self::new()
    }
}

impl BaudLink {
    /// A link model at the paper's `DEFAULT_BAUD_RATE` with zero latency.
    pub fn new() -> BaudLink {
        BaudLink {
            rates: HashMap::new(),
            default_rate: tags::DEFAULT_BAUD_RATE,
            latency: HashMap::new(),
            default_latency: 0.0,
        }
    }

    /// Infinite-bandwidth, zero-latency network (pure scheduling studies —
    /// the paper's §5 experiments effectively ignore staging delays).
    pub fn instantaneous() -> BaudLink {
        let mut link = BaudLink::new();
        link.default_rate = f64::INFINITY;
        link
    }

    /// Builder: the baud rate used by entities without an explicit rate.
    pub fn with_default_rate(mut self, baud: f64) -> BaudLink {
        assert!(baud > 0.0);
        self.default_rate = baud;
        self
    }

    /// Builder: the latency used by pairs without an explicit override.
    pub fn with_default_latency(mut self, latency: f64) -> BaudLink {
        assert!(latency >= 0.0);
        self.default_latency = latency;
        self
    }

    /// Set an entity's port baud rate.
    pub fn set_rate(&mut self, entity: EntityId, baud: f64) {
        assert!(baud > 0.0);
        self.rates.insert(entity, baud);
    }

    /// Set a symmetric one-way latency between two entities.
    pub fn set_latency(&mut self, a: EntityId, b: EntityId, latency: f64) {
        assert!(latency >= 0.0);
        self.latency.insert((a.min(b), a.max(b)), latency);
    }

    fn rate_of(&self, e: EntityId) -> f64 {
        self.rates.get(&e).copied().unwrap_or(self.default_rate)
    }

    fn latency_of(&self, a: EntityId, b: EntityId) -> f64 {
        self.latency
            .get(&(a.min(b), a.max(b)))
            .copied()
            .unwrap_or(self.default_latency)
    }
}

impl LinkModel for BaudLink {
    fn delay(&self, src: EntityId, dst: EntityId, bytes: u64) -> f64 {
        if src == dst {
            return 0.0; // self-messages don't cross the network
        }
        let rate = self.rate_of(src).min(self.rate_of(dst));
        let transfer = if rate.is_infinite() { 0.0 } else { bytes as f64 * 8.0 / rate };
        self.latency_of(src, dst) + transfer
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_baud_9600() {
        let link = BaudLink::new();
        // 1200 bytes = 9600 bits at 9600 baud → 1.0 time unit.
        assert!((link.delay(0, 1, 1200) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn slower_endpoint_bounds() {
        let mut link = BaudLink::new().with_default_rate(1_000_000.0);
        link.set_rate(1, 9600.0);
        assert!((link.delay(0, 1, 1200) - 1.0).abs() < 1e-12);
        assert!((link.delay(1, 0, 1200) - 1.0).abs() < 1e-12);
        assert!(link.delay(0, 2, 1200) < 0.01);
    }

    #[test]
    fn latency_added() {
        let mut link = BaudLink::instantaneous();
        link.set_latency(0, 1, 0.25);
        assert_eq!(link.delay(0, 1, 1_000_000), 0.25);
        assert_eq!(link.delay(1, 0, 1_000_000), 0.25);
        assert_eq!(link.delay(0, 2, 1_000_000), 0.0);
    }

    #[test]
    fn self_messages_free() {
        let link = BaudLink::new().with_default_latency(5.0);
        assert_eq!(link.delay(3, 3, 10_000), 0.0);
    }

    #[test]
    fn instantaneous_is_zero() {
        let link = BaudLink::instantaneous();
        assert_eq!(link.delay(0, 1, u64::MAX / 16), 0.0);
    }

    #[test]
    fn zero_bytes_latency_only() {
        let link = BaudLink::new().with_default_latency(0.5);
        assert_eq!(link.delay(0, 1, 0), 0.5);
    }
}
