//! `gridsim.GridStatistics` + `gridsim.Accumulator` (paper §3.6): an entity
//! that records labelled, timestamped measurements from other entities, and
//! a placeholder for summary statistics over a data series.

use super::messages::Msg;
use super::tags;
use crate::des::{Ctx, Entity, Event};
use std::sync::Arc;

/// One recorded measurement.
#[derive(Debug, Clone)]
pub struct StatRecord {
    /// Simulation time the measurement was taken.
    pub time: f64,
    /// Dotted category, e.g. `"*.USER.TimeUtilization"` in the paper's
    /// report-writer configuration. `Arc<str>` so per-completion records can
    /// share one precomputed category string instead of formatting a fresh
    /// `String` on every emission.
    pub category: Arc<str>,
    /// Free-form measurement label.
    pub label: String,
    /// The measured value.
    pub value: f64,
}

/// `gridsim.Accumulator` — running mean/sum/σ/min/max of a series.
#[derive(Debug, Clone, Default)]
pub struct Accumulator {
    n: u64,
    sum: f64,
    sum_sq: f64,
    min: f64,
    max: f64,
}

impl Accumulator {
    /// An empty accumulator.
    pub fn new() -> Accumulator {
        Accumulator { n: 0, sum: 0.0, sum_sq: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    /// Fold one value into the running statistics.
    pub fn add(&mut self, x: f64) {
        self.n += 1;
        self.sum += x;
        self.sum_sq += x * x;
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    /// Number of values added.
    pub fn count(&self) -> u64 {
        self.n
    }

    /// Sum of the values added.
    pub fn sum(&self) -> f64 {
        self.sum
    }

    /// Mean of the values added (0 while empty).
    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.sum / self.n as f64
        }
    }

    /// Population standard deviation.
    pub fn std_dev(&self) -> f64 {
        if self.n == 0 {
            return 0.0;
        }
        let mean = self.mean();
        (self.sum_sq / self.n as f64 - mean * mean).max(0.0).sqrt()
    }

    /// Smallest value added (0 while empty).
    pub fn min(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.min
        }
    }

    /// Largest value added (0 while empty).
    pub fn max(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.max
        }
    }
}

/// The statistics entity: a passive sink for `RECORD_STATISTICS` events.
/// After the run, the report writer reads `records()` / `accumulator_for()`.
pub struct GridStatistics {
    name: String,
    records: Vec<StatRecord>,
}

impl GridStatistics {
    /// A statistics entity with no records yet.
    pub fn new(name: impl Into<String>) -> GridStatistics {
        GridStatistics { name: name.into(), records: Vec::new() }
    }

    /// Every recorded measurement, in arrival order.
    pub fn records(&self) -> &[StatRecord] {
        &self.records
    }

    /// All records whose category matches `pattern`, where a leading `*.`
    /// matches any prefix (the paper's category syntax, e.g.
    /// `"*.USER.TimeUtilization"`).
    pub fn matching(&self, pattern: &str) -> Vec<&StatRecord> {
        self.records.iter().filter(|r| category_matches(pattern, &r.category)).collect()
    }

    /// Accumulator over all values in a category.
    pub fn accumulator_for(&self, pattern: &str) -> Accumulator {
        let mut acc = Accumulator::new();
        for r in self.matching(pattern) {
            acc.add(r.value);
        }
        acc
    }
}

/// `*.X.Y` matches any category ending with `.X.Y` (or equal to `X.Y`);
/// otherwise exact match.
fn category_matches(pattern: &str, category: &str) -> bool {
    match pattern.strip_prefix("*.") {
        Some(suffix) => {
            category == suffix || category.ends_with(&format!(".{suffix}"))
        }
        None => pattern == category,
    }
}

impl Entity<Msg> for GridStatistics {
    fn name(&self) -> &str {
        &self.name
    }

    fn on_event(&mut self, _ctx: &mut Ctx<Msg>, mut ev: Event<Msg>) {
        match ev.tag {
            tags::RECORD_STATISTICS => {
                let Msg::Stat(record) = ev.take_data() else {
                    panic!("RECORD_STATISTICS without payload")
                };
                self.records.push(record);
            }
            tags::INSIGNIFICANT => {}
            other => panic!("statistics entity got unexpected tag {other}"),
        }
    }

    fn as_any(&self) -> &dyn std::any::Any {
        self
    }
    fn as_any_mut(&mut self) -> &mut dyn std::any::Any {
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulator_summary() {
        let mut a = Accumulator::new();
        for x in [1.0, 2.0, 3.0, 4.0] {
            a.add(x);
        }
        assert_eq!(a.count(), 4);
        assert_eq!(a.sum(), 10.0);
        assert_eq!(a.mean(), 2.5);
        assert_eq!(a.min(), 1.0);
        assert_eq!(a.max(), 4.0);
        // Population σ of 1..4 = sqrt(1.25).
        assert!((a.std_dev() - 1.25f64.sqrt()).abs() < 1e-12);
    }

    #[test]
    fn accumulator_empty() {
        let a = Accumulator::new();
        assert_eq!(a.mean(), 0.0);
        assert_eq!(a.std_dev(), 0.0);
        assert_eq!(a.min(), 0.0);
        assert_eq!(a.max(), 0.0);
    }

    #[test]
    fn category_wildcards() {
        assert!(category_matches("*.USER.Time", "U1.USER.Time"));
        assert!(category_matches("*.USER.Time", "USER.Time"));
        assert!(!category_matches("*.USER.Time", "U1.USER.Budget"));
        assert!(category_matches("exact", "exact"));
        assert!(!category_matches("exact", "not.exact2"));
        // Suffix must align on a dot boundary.
        assert!(!category_matches("*.SER.Time", "U1.USER.Time"));
    }

    #[test]
    fn matching_and_accumulating() {
        let mut s = GridStatistics::new("stats");
        for (cat, v) in [
            ("U1.USER.Time", 1.0),
            ("U2.USER.Time", 3.0),
            ("U1.USER.Budget", 100.0),
        ] {
            s.records.push(StatRecord {
                time: 0.0,
                category: cat.into(),
                label: "x".into(),
                value: v,
            });
        }
        assert_eq!(s.matching("*.USER.Time").len(), 2);
        let acc = s.accumulator_for("*.USER.Time");
        assert_eq!(acc.mean(), 2.0);
    }
}
