//! The message payload type exchanged between GridSim entities.
//!
//! SimJava events carry opaque object payloads; we use one closed enum so
//! event payloads stay allocation-cheap and the protocol surface is explicit.

use super::gridlet::Gridlet;
use super::statistics::StatRecord;
use crate::des::EntityId;
use std::sync::Arc;

/// Static resource information returned by a `RESOURCE_CHARACTERISTICS`
/// query (what the broker's "resource trading" step needs).
#[derive(Debug, Clone)]
pub struct ResourceInfo {
    /// The resource's entity id.
    pub id: EntityId,
    /// The resource's entity name (Table 2's "name"). Interned as `Arc<str>`:
    /// every `Register`/`Characteristics` reply clones this info, and a
    /// shared pointer keeps those clones off the allocator on the hot path.
    pub name: Arc<str>,
    /// Total PEs across the resource's machines.
    pub num_pe: usize,
    /// Rating of one PE (homogeneous assumption, as in the paper).
    pub mips_per_pe: f64,
    /// Price in G$ per PE per time unit (Table 2 "Price").
    pub cost_per_pe_time: f64,
    /// `true` for time-shared managers, `false` for space-shared.
    pub time_shared: bool,
    /// Time-zone offset in hours (drives the local-load calendar).
    pub time_zone: f64,
}

impl ResourceInfo {
    /// G$ per MI — the broker's ranking key for cost optimization.
    pub fn cost_per_mi(&self) -> f64 {
        self.cost_per_pe_time / self.mips_per_pe
    }

    /// Aggregate MIPS.
    pub fn total_mips(&self) -> f64 {
        self.mips_per_pe * self.num_pe as f64
    }
}

/// Dynamic resource state returned by a `RESOURCE_DYNAMICS` query.
#[derive(Debug, Clone)]
pub struct ResourceDynamics {
    /// The resource's entity id.
    pub id: EntityId,
    /// Gridlets currently executing.
    pub in_exec: usize,
    /// Gridlets waiting in the queue (space-shared).
    pub queued: usize,
    /// Background (non-grid) load factor currently in effect.
    pub local_load: f64,
    /// Whether the resource is up (failure injection).
    pub available: bool,
}

/// Advance-reservation request (paper §3.1 feature / future work §6).
#[derive(Debug, Clone)]
pub struct ReservationRequest {
    /// Caller-chosen id echoed back in the reply.
    pub reservation_id: usize,
    /// Requested start time.
    pub start: f64,
    /// Requested slot length.
    pub duration: f64,
    /// PEs to reserve.
    pub num_pe: usize,
}

/// Advance-reservation reply.
#[derive(Debug, Clone)]
pub struct ReservationReply {
    /// The id from the matching [`ReservationRequest`].
    pub reservation_id: usize,
    /// Whether the resource granted the slot.
    pub accepted: bool,
}

/// Event payloads.
#[derive(Debug, Clone)]
pub enum Msg {
    /// A Gridlet in flight (submit / return / cancel-reply).
    Gridlet(Box<Gridlet>),
    /// Gridlet id (status query / cancel request).
    GridletId(usize),
    /// Resource -> GIS registration.
    Register(ResourceInfo),
    /// GIS -> broker: ids of registered resources.
    ResourceIds(Vec<EntityId>),
    /// Resource -> broker: static characteristics.
    Characteristics(ResourceInfo),
    /// Resource -> broker: dynamic state.
    Dynamics(ResourceDynamics),
    /// Entity -> statistics: one measurement.
    Stat(StatRecord),
    /// Broker/user -> resource: reservation protocol request.
    Reserve(ReservationRequest),
    /// Resource -> requester: reservation protocol reply.
    ReserveReply(ReservationReply),
    /// User -> broker: a materialized experiment to schedule.
    Experiment(Box<crate::broker::experiment::Experiment>),
    /// Broker -> user: experiment outcome.
    ExperimentResult(Box<crate::broker::experiment::ExperimentResult>),
    /// Generic control payload (user/broker handshakes).
    Control(u64),
    /// Resource -> subscribed brokers: new dynamic price in G$ per PE per
    /// time unit (the resource is identified by the event source).
    Price(f64),
}

impl Msg {
    /// Approximate on-the-wire size in bytes, used by the network model to
    /// derive transfer delays. Gridlets dominate: their input/output file
    /// sizes are the paper's staging traffic.
    pub fn wire_bytes(&self, outbound: bool) -> u64 {
        match self {
            // Dispatching a gridlet ships its input file; returning it ships
            // the output file. A small fixed header covers the job metadata.
            Msg::Gridlet(g) => 128 + if outbound { g.input_bytes } else { g.output_bytes },
            Msg::ResourceIds(ids) => 16 + 8 * ids.len() as u64,
            Msg::GridletId(_) | Msg::Control(_) | Msg::Price(_) => 16,
            Msg::Register(_) | Msg::Characteristics(_) => 128,
            Msg::Dynamics(_) => 64,
            Msg::Stat(_) => 48,
            Msg::Reserve(_) | Msg::ReserveReply(_) => 64,
            Msg::Experiment(e) => 256 + 64 * e.gridlets.len() as u64,
            Msg::ExperimentResult(_) => 512,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn resource_info_cost_per_mi() {
        let info = ResourceInfo {
            id: 1,
            name: "R4".into(),
            num_pe: 2,
            mips_per_pe: 380.0,
            cost_per_pe_time: 2.0,
            time_shared: true,
            time_zone: 1.0,
        };
        assert!((info.cost_per_mi() - 2.0 / 380.0).abs() < 1e-15);
        assert_eq!(info.total_mips(), 760.0);
    }

    #[test]
    fn gridlet_wire_size_directional() {
        let mut g = Gridlet::new(0, 100.0, 1000, 50);
        g.owner = 1;
        let m = Msg::Gridlet(Box::new(g));
        assert_eq!(m.wire_bytes(true), 1128);
        assert_eq!(m.wire_bytes(false), 178);
    }

    #[test]
    fn id_list_scales() {
        let m = Msg::ResourceIds(vec![1, 2, 3]);
        assert_eq!(m.wire_bytes(true), 40);
    }
}
