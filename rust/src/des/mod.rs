//! Deterministic discrete-event simulation kernel.
//!
//! This is the substrate the paper borrows from SimJava [1]: a future-event
//! queue ordered by timestamp, entities that exchange timestamped events, and
//! a simulation clock that jumps from event to event. SimJava realises
//! entities as Java threads blocked in `sim_wait()`; the observable semantics
//! are just "deliver events in (time, insertion) order to a handler that may
//! schedule more events". We implement exactly those semantics with an
//! explicit event loop and an [`Entity::on_event`] trait method — fully
//! deterministic (no thread interleavings), allocation-light, and fast.
//!
//! The mapping from SimJava primitives:
//!
//! | SimJava                   | here                                   |
//! |---------------------------|----------------------------------------|
//! | `sim_schedule(dst, d, t)` | [`Ctx::send_delayed`] / [`Ctx::send`]  |
//! | `sim_hold(d)`             | [`Ctx::schedule_self`] + handler state |
//! | `sim_wait(ev)`            | returning from `on_event`              |
//! | `Sim_system` future queue | [`queue::EventQueue`] (flat 4-ary heap)|
//!
//! # The event loop and the stepped execution contract
//!
//! A [`Simulation`] moves through four idempotent phases:
//!
//! 1. [`Simulation::init`] — run every entity's
//!    [`Entity::on_start`] hook in entity-id order at time 0. This is where
//!    resources register with the information service and users kick off
//!    experiments; it dispatches no events itself. Implicit before the
//!    first step, so explicit calls are only needed to observe pre-event
//!    state.
//! 2. [`Simulation::step`] / [`Simulation::run_until`] — dispatch the
//!    earliest pending event (or every event due by a horizon). The clock
//!    jumps from event to event; ties break FIFO by insertion sequence, so
//!    dispatch order is fully deterministic. Both route through one
//!    [`Simulation::step_before`] hot path, so a horizon check never pays
//!    a separate peek-then-pop pass over the queue.
//! 3. [`Simulation::run`] — `init`, then `step` until idle (queue drained,
//!    an entity called [`Ctx::stop`], or a [`SimConfig`] limit hit), then
//!    `finalize`.
//! 4. [`Simulation::finalize`] — run every entity's [`Entity::on_end`]
//!    reporting hook and return the final clock.
//!
//! The contract tying them together: **any interleaving of `step` and
//! `run_until` calls produces results bit-identical to one `run`** — the
//! stepped API adds observation points, never different semantics (pinned
//! by the kernel's `stepped_run_matches_run` test and, end to end, by
//! `rust/tests/session_stepping.rs`).

pub mod entity;
pub mod event;
pub mod queue;
pub mod sim;

pub use entity::{Ctx, Entity, EntityId};
pub use event::{Event, EventKind};
pub use queue::EventQueue;
pub use sim::{SimConfig, Simulation};
