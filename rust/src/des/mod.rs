//! Deterministic discrete-event simulation kernel.
//!
//! This is the substrate the paper borrows from SimJava [1]: a future-event
//! queue ordered by timestamp, entities that exchange timestamped events, and
//! a simulation clock that jumps from event to event. SimJava realises
//! entities as Java threads blocked in `sim_wait()`; the observable semantics
//! are just "deliver events in (time, insertion) order to a handler that may
//! schedule more events". We implement exactly those semantics with an
//! explicit event loop and an [`Entity::on_event`] trait method — fully
//! deterministic (no thread interleavings), allocation-light, and fast.
//!
//! The mapping from SimJava primitives:
//!
//! | SimJava                   | here                                   |
//! |---------------------------|----------------------------------------|
//! | `sim_schedule(dst, d, t)` | [`Ctx::send_delayed`] / [`Ctx::send`]  |
//! | `sim_hold(d)`             | [`Ctx::schedule_self`] + handler state |
//! | `sim_wait(ev)`            | returning from `on_event`              |
//! | `Sim_system` future queue | [`queue::EventQueue`] (binary heap)    |

pub mod entity;
pub mod event;
pub mod queue;
pub mod sim;

pub use entity::{Ctx, Entity, EntityId};
pub use event::{Event, EventKind};
pub use queue::EventQueue;
pub use sim::{SimConfig, Simulation};
