//! The future-event queue: a flat 4-ary min-heap ordered by `(time, seq)`.
//!
//! SimJava's `Sim_system` keeps a "timestamp ordered queue of future events";
//! ties are broken by insertion order so simultaneous events are FIFO. We get
//! the same semantics from `(time, seq)` lexicographic ordering where `seq`
//! is assigned at insertion.
//!
//! # Layout (the kernel hot path)
//!
//! The heap itself holds only 20-byte [`HeapKey`]s — `(time_bits, seq, slot)`
//! — in a flat `Vec`, laid out as a 4-ary tree (children of `i` are
//! `4i+1..=4i+4`). Event payloads live in a slot-recycled slab next to it, so
//! sift operations move small `Copy` keys instead of full `Event<M>` values
//! (~120 bytes under `gridsim::Msg`), and a 4-ary node's children share one
//! cache line. Timestamps are compared as raw bit patterns: for the
//! non-negative finite range enforced at [`push`](EventQueue::push), the IEEE
//! 754 encoding of `f64` is monotone, so a `u64` compare is a total-order
//! time compare (a `-0.0` timestamp is canonicalized to `+0.0` on insertion,
//! which also keeps it tie-FIFO with `0.0`). Slab slots are pushed to a free
//! list on pop, so a steady-state simulation stops allocating once the queue
//! has reached its high-water mark.
//!
//! Pop order is part of the kernel's determinism contract: every replacement
//! queue must preserve exact `(time, seq)` lexicographic pops, which
//! `rust/tests/queue_equivalence.rs` pins differentially against a reference
//! `BinaryHeap` implementation.

use super::event::Event;

/// Heap arity. 4 keeps the tree half as deep as a binary heap and lets one
/// node's children share a cache line (4 × 24-byte padded keys).
const D: usize = 4;

/// Compact heap entry: canonical time bits, insertion sequence number, and
/// the slab slot holding the event payload. Lexicographic derive order is
/// `(time_bits, seq, slot)`; `seq` is unique, so `slot` never decides.
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
struct HeapKey {
    time_bits: u64,
    seq: u64,
    slot: u32,
}

/// Future-event queue.
pub struct EventQueue<M> {
    /// Flat 4-ary min-heap of keys (see [`HeapKey`]).
    keys: Vec<HeapKey>,
    /// Event payloads, indexed by `HeapKey::slot`.
    slab: Vec<Option<Event<M>>>,
    /// Slab slots freed by pops, reused by pushes.
    free: Vec<u32>,
    next_seq: u64,
}

impl<M> Default for EventQueue<M> {
    fn default() -> Self {
        Self::new()
    }
}

impl<M> EventQueue<M> {
    /// An empty queue; sequence numbers start at 0.
    pub fn new() -> Self {
        EventQueue { keys: Vec::new(), slab: Vec::new(), free: Vec::new(), next_seq: 0 }
    }

    /// Number of pending events.
    pub fn len(&self) -> usize {
        self.keys.len()
    }

    /// True when no event is pending.
    pub fn is_empty(&self) -> bool {
        self.keys.is_empty()
    }

    /// Out-of-line rejection of invalid timestamps, so the happy path of
    /// [`push`](Self::push) carries a single predictable branch instead of
    /// two formatting `assert!`s. NaN/negative times are always caller bugs.
    #[cold]
    #[inline(never)]
    fn reject_time(time: f64) -> ! {
        assert!(time.is_finite(), "event time must be finite, got {time}");
        panic!("event time must be >= 0, got {time}");
    }

    /// Insert an event; assigns its sequence number. Panics on NaN or
    /// negative-time events — those are always bugs in the caller. A `-0.0`
    /// timestamp is canonicalized to `+0.0`.
    pub fn push(&mut self, mut ev: Event<M>) -> u64 {
        if !(ev.time >= 0.0 && ev.time.is_finite()) {
            Self::reject_time(ev.time);
        }
        // `+ 0.0` maps -0.0 to +0.0 and is the identity elsewhere, so the
        // bit-pattern compare below is a total order over stored times.
        ev.time += 0.0;
        let seq = self.next_seq;
        self.next_seq += 1;
        ev.seq = seq;
        let key = HeapKey { time_bits: ev.time.to_bits(), seq, slot: 0 };
        let slot = match self.free.pop() {
            Some(s) => {
                self.slab[s as usize] = Some(ev);
                s
            }
            None => {
                let s = self.slab.len();
                assert!(s < u32::MAX as usize, "event queue slab overflow");
                self.slab.push(Some(ev));
                s as u32
            }
        };
        self.keys.push(HeapKey { slot, ..key });
        self.sift_up(self.keys.len() - 1);
        seq
    }

    /// Pop the earliest event (smallest `(time, seq)`).
    pub fn pop(&mut self) -> Option<Event<M>> {
        let root = *self.keys.first()?;
        Some(self.remove_root(root))
    }

    /// Pop the earliest event only if its timestamp is ≤ `horizon`; a single
    /// root comparison replaces the peek-then-pop double heap access of a
    /// bounded event loop (see `Simulation::step_before`).
    pub fn pop_before(&mut self, horizon: f64) -> Option<Event<M>> {
        let root = *self.keys.first()?;
        if f64::from_bits(root.time_bits) > horizon {
            return None;
        }
        Some(self.remove_root(root))
    }

    /// Peek at the earliest event's timestamp.
    pub fn peek_time(&self) -> Option<f64> {
        self.keys.first().map(|k| f64::from_bits(k.time_bits))
    }

    fn remove_root(&mut self, root: HeapKey) -> Event<M> {
        let last = self.keys.pop().expect("remove_root on empty heap");
        if !self.keys.is_empty() {
            self.keys[0] = last;
            self.sift_down(0);
        }
        let ev = self.slab[root.slot as usize].take().expect("heap key points at a full slot");
        self.free.push(root.slot);
        ev
    }

    /// Hole-based sift toward the root: each displaced key moves once.
    fn sift_up(&mut self, mut pos: usize) {
        let key = self.keys[pos];
        while pos > 0 {
            let parent = (pos - 1) / D;
            if self.keys[parent] <= key {
                break;
            }
            self.keys[pos] = self.keys[parent];
            pos = parent;
        }
        self.keys[pos] = key;
    }

    /// Hole-based sift toward the leaves: pick the smallest of ≤ 4 children.
    fn sift_down(&mut self, mut pos: usize) {
        let len = self.keys.len();
        let key = self.keys[pos];
        loop {
            let first = D * pos + 1;
            if first >= len {
                break;
            }
            let end = (first + D).min(len);
            let mut min_child = first;
            for c in first + 1..end {
                if self.keys[c] < self.keys[min_child] {
                    min_child = c;
                }
            }
            if key <= self.keys[min_child] {
                break;
            }
            self.keys[pos] = self.keys[min_child];
            pos = min_child;
        }
        self.keys[pos] = key;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::des::event::EventKind;

    fn ev(time: f64, tag: i64) -> Event<u32> {
        Event { time, seq: 0, src: 0, dst: 0, tag, kind: EventKind::External, data: None }
    }

    #[test]
    fn orders_by_time() {
        let mut q = EventQueue::new();
        q.push(ev(3.0, 1));
        q.push(ev(1.0, 2));
        q.push(ev(2.0, 3));
        assert_eq!(q.pop().unwrap().tag, 2);
        assert_eq!(q.pop().unwrap().tag, 3);
        assert_eq!(q.pop().unwrap().tag, 1);
        assert!(q.pop().is_none());
    }

    #[test]
    fn fifo_on_ties() {
        let mut q = EventQueue::new();
        for tag in 0..100 {
            q.push(ev(5.0, tag));
        }
        for tag in 0..100 {
            assert_eq!(q.pop().unwrap().tag, tag, "simultaneous events must be FIFO");
        }
    }

    #[test]
    fn seq_assigned_monotonically() {
        let mut q = EventQueue::new();
        let a = q.push(ev(1.0, 0));
        let b = q.push(ev(0.5, 1));
        assert!(b > a);
    }

    #[test]
    fn peek_time() {
        let mut q = EventQueue::new();
        assert!(q.peek_time().is_none());
        q.push(ev(9.0, 0));
        q.push(ev(4.0, 1));
        assert_eq!(q.peek_time(), Some(4.0));
    }

    #[test]
    #[should_panic(expected = "finite")]
    fn rejects_nan() {
        let mut q = EventQueue::new();
        q.push(ev(f64::NAN, 0));
    }

    #[test]
    #[should_panic(expected = "finite")]
    fn rejects_infinite() {
        let mut q = EventQueue::new();
        q.push(ev(f64::INFINITY, 0));
    }

    #[test]
    #[should_panic(expected = ">= 0")]
    fn rejects_negative() {
        let mut q = EventQueue::new();
        q.push(ev(-1.0, 0));
    }

    #[test]
    fn interleaved_push_pop_keeps_order() {
        let mut q = EventQueue::new();
        q.push(ev(10.0, 10));
        q.push(ev(1.0, 1));
        assert_eq!(q.pop().unwrap().tag, 1);
        q.push(ev(5.0, 5));
        q.push(ev(2.0, 2));
        assert_eq!(q.pop().unwrap().tag, 2);
        assert_eq!(q.pop().unwrap().tag, 5);
        assert_eq!(q.pop().unwrap().tag, 10);
    }

    #[test]
    fn negative_zero_is_canonicalized_and_fifo_with_zero() {
        let mut q = EventQueue::new();
        q.push(ev(0.0, 1));
        q.push(ev(-0.0, 2));
        q.push(ev(0.0, 3));
        // All three are time 0.0 after canonicalization → FIFO by seq.
        for expected in [1, 2, 3] {
            let e = q.pop().unwrap();
            assert_eq!(e.tag, expected);
            assert_eq!(e.time.to_bits(), 0.0f64.to_bits(), "-0.0 stored as +0.0");
        }
    }

    #[test]
    fn pop_before_respects_horizon() {
        let mut q = EventQueue::new();
        q.push(ev(1.0, 1));
        q.push(ev(2.0, 2));
        q.push(ev(3.0, 3));
        assert!(q.pop_before(0.5).is_none());
        assert_eq!(q.pop_before(2.0).unwrap().tag, 1);
        assert_eq!(q.pop_before(2.0).unwrap().tag, 2);
        assert!(q.pop_before(2.0).is_none(), "next event is past the horizon");
        assert_eq!(q.len(), 1);
        assert_eq!(q.pop_before(f64::INFINITY).unwrap().tag, 3);
        assert!(q.pop_before(f64::INFINITY).is_none(), "empty queue");
    }

    #[test]
    fn slab_slots_are_recycled() {
        let mut q = EventQueue::new();
        for i in 0..1000 {
            q.push(ev(i as f64, i));
            assert_eq!(q.pop().unwrap().tag, i);
        }
        assert_eq!(q.slab.len(), 1, "sequential push/pop reuses one slab slot");
        // High-water mark sizes the slab; it never grows past it.
        for i in 0..16 {
            q.push(ev(i as f64, i));
        }
        while q.pop().is_some() {}
        assert_eq!(q.slab.len(), 16);
        assert_eq!(q.free.len(), 16);
    }

    #[test]
    fn large_randomized_heap_pops_sorted() {
        // Deterministic LCG-driven stress: pop order must be (time, seq).
        let mut q = EventQueue::new();
        let mut state: u64 = 0x9E37_79B9_7F4A_7C15;
        for i in 0..5000 {
            state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            // Coarse grid of times to force plenty of ties.
            let t = ((state >> 33) % 97) as f64 * 0.5;
            q.push(ev(t, i));
        }
        let mut prev: Option<(u64, u64)> = None;
        while let Some(e) = q.pop() {
            let key = (e.time.to_bits(), e.seq);
            if let Some(p) = prev {
                assert!(p < key, "pops must be strictly increasing in (time, seq)");
            }
            prev = Some(key);
        }
    }
}
