//! The future-event queue: a binary min-heap ordered by `(time, seq)`.
//!
//! SimJava's `Sim_system` keeps a "timestamp ordered queue of future events";
//! ties are broken by insertion order so simultaneous events are FIFO. We get
//! the same semantics from `(time, seq)` lexicographic ordering where `seq`
//! is assigned at insertion.

use super::event::Event;
use std::cmp::Ordering;
use std::collections::BinaryHeap;

struct HeapEntry<M>(Event<M>);

impl<M> PartialEq for HeapEntry<M> {
    fn eq(&self, other: &Self) -> bool {
        self.0.time == other.0.time && self.0.seq == other.0.seq
    }
}
impl<M> Eq for HeapEntry<M> {}

impl<M> PartialOrd for HeapEntry<M> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl<M> Ord for HeapEntry<M> {
    fn cmp(&self, other: &Self) -> Ordering {
        // Reverse: BinaryHeap is a max-heap, we want the *earliest* event on
        // top. NaN times are rejected at insertion so total_cmp is safe.
        other
            .0
            .time
            .total_cmp(&self.0.time)
            .then_with(|| other.0.seq.cmp(&self.0.seq))
    }
}

/// Future-event queue.
pub struct EventQueue<M> {
    heap: BinaryHeap<HeapEntry<M>>,
    next_seq: u64,
}

impl<M> Default for EventQueue<M> {
    fn default() -> Self {
        Self::new()
    }
}

impl<M> EventQueue<M> {
    /// An empty queue; sequence numbers start at 0.
    pub fn new() -> Self {
        EventQueue { heap: BinaryHeap::new(), next_seq: 0 }
    }

    /// Number of pending events.
    pub fn len(&self) -> usize {
        self.heap.len()
    }

    /// True when no event is pending.
    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Insert an event; assigns its sequence number. Panics on NaN or
    /// negative-time events — those are always bugs in the caller.
    pub fn push(&mut self, mut ev: Event<M>) -> u64 {
        assert!(ev.time.is_finite(), "event time must be finite, got {}", ev.time);
        assert!(ev.time >= 0.0, "event time must be >= 0, got {}", ev.time);
        let seq = self.next_seq;
        self.next_seq += 1;
        ev.seq = seq;
        self.heap.push(HeapEntry(ev));
        seq
    }

    /// Pop the earliest event (smallest `(time, seq)`).
    pub fn pop(&mut self) -> Option<Event<M>> {
        self.heap.pop().map(|e| e.0)
    }

    /// Peek at the earliest event's timestamp.
    pub fn peek_time(&self) -> Option<f64> {
        self.heap.peek().map(|e| e.0.time)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::des::event::EventKind;

    fn ev(time: f64, tag: i64) -> Event<u32> {
        Event { time, seq: 0, src: 0, dst: 0, tag, kind: EventKind::External, data: None }
    }

    #[test]
    fn orders_by_time() {
        let mut q = EventQueue::new();
        q.push(ev(3.0, 1));
        q.push(ev(1.0, 2));
        q.push(ev(2.0, 3));
        assert_eq!(q.pop().unwrap().tag, 2);
        assert_eq!(q.pop().unwrap().tag, 3);
        assert_eq!(q.pop().unwrap().tag, 1);
        assert!(q.pop().is_none());
    }

    #[test]
    fn fifo_on_ties() {
        let mut q = EventQueue::new();
        for tag in 0..100 {
            q.push(ev(5.0, tag));
        }
        for tag in 0..100 {
            assert_eq!(q.pop().unwrap().tag, tag, "simultaneous events must be FIFO");
        }
    }

    #[test]
    fn seq_assigned_monotonically() {
        let mut q = EventQueue::new();
        let a = q.push(ev(1.0, 0));
        let b = q.push(ev(0.5, 1));
        assert!(b > a);
    }

    #[test]
    fn peek_time() {
        let mut q = EventQueue::new();
        assert!(q.peek_time().is_none());
        q.push(ev(9.0, 0));
        q.push(ev(4.0, 1));
        assert_eq!(q.peek_time(), Some(4.0));
    }

    #[test]
    #[should_panic(expected = "finite")]
    fn rejects_nan() {
        let mut q = EventQueue::new();
        q.push(ev(f64::NAN, 0));
    }

    #[test]
    #[should_panic(expected = ">= 0")]
    fn rejects_negative() {
        let mut q = EventQueue::new();
        q.push(ev(-1.0, 0));
    }

    #[test]
    fn interleaved_push_pop_keeps_order() {
        let mut q = EventQueue::new();
        q.push(ev(10.0, 10));
        q.push(ev(1.0, 1));
        assert_eq!(q.pop().unwrap().tag, 1);
        q.push(ev(5.0, 5));
        q.push(ev(2.0, 2));
        assert_eq!(q.pop().unwrap().tag, 2);
        assert_eq!(q.pop().unwrap().tag, 5);
        assert_eq!(q.pop().unwrap().tag, 10);
    }
}
