//! Simulation events.

/// Identifier of a simulation entity (index into the simulation's entity
/// table). The paper's entities are identified by unique names; we keep the
/// name in the entity and use dense ids on the wire.
pub type EntityId = usize;

/// Whether an event came from another entity or was scheduled by the
/// destination entity on itself.
///
/// The paper distinguishes *internal* events (self-scheduled, e.g. the
/// forecast completion interrupts of Figs 7/10) from *external* events
/// (Gridlet arrivals, queries). Internal events carry a tag-matching rule:
/// only the most recently scheduled internal event is meaningful; stale ones
/// are discarded by the receiving entity.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EventKind {
    /// Event sent by another entity (possibly via the simulated network).
    External,
    /// Event an entity scheduled on itself.
    Internal,
    /// Kernel-internal finish marker for a shared-bandwidth network flow
    /// (see [`crate::network`]). Never dispatched to an entity: the kernel
    /// intercepts it in `step()`, and either drops it (a recompute
    /// superseded it — its `seq` no longer matches the flow's live marker)
    /// or completes the flow and emits the payload as a fresh `External`
    /// event. The event's `tag` carries the flow id, not a protocol tag.
    /// Markers are counted in `events_processed` and shown to the observer.
    FlowWake,
}

/// A timestamped event, generic over the message payload type `M`.
///
/// `seq` is a global monotonically increasing sequence number used to break
/// timestamp ties deterministically (FIFO among simultaneous events), which
/// mirrors SimJava's insertion-ordered future queue.
#[derive(Debug, Clone)]
pub struct Event<M> {
    /// Delivery time in simulation time units.
    pub time: f64,
    /// Global insertion sequence number (tie-breaker).
    pub seq: u64,
    /// Sending entity.
    pub src: EntityId,
    /// Receiving entity.
    pub dst: EntityId,
    /// Protocol tag (see `gridsim::tags`): selects the service requested.
    pub tag: i64,
    /// Internal vs external (paper §3.4).
    pub kind: EventKind,
    /// Optional payload.
    pub data: Option<M>,
}

impl<M> Event<M> {
    /// True if this is a self-scheduled (internal) event.
    pub fn is_internal(&self) -> bool {
        self.kind == EventKind::Internal
    }

    /// Take the payload out of the event, panicking with a useful message if
    /// absent or if the caller expected a payload the sender did not attach.
    pub fn take_data(&mut self) -> M {
        self.data
            .take()
            .unwrap_or_else(|| panic!("event tag {} from {} had no payload", self.tag, self.src))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn internal_flag() {
        let ev: Event<()> = Event {
            time: 1.0,
            seq: 0,
            src: 0,
            dst: 0,
            tag: 7,
            kind: EventKind::Internal,
            data: None,
        };
        assert!(ev.is_internal());
        let ev2 = Event { kind: EventKind::External, ..ev };
        assert!(!ev2.is_internal());
    }

    #[test]
    fn take_data_moves_payload() {
        let mut ev = Event {
            time: 0.0,
            seq: 0,
            src: 1,
            dst: 2,
            tag: 3,
            kind: EventKind::External,
            data: Some(42u32),
        };
        assert_eq!(ev.take_data(), 42);
        assert!(ev.data.is_none());
    }

    #[test]
    #[should_panic(expected = "no payload")]
    fn take_data_panics_when_empty() {
        let mut ev: Event<u32> = Event {
            time: 0.0,
            seq: 0,
            src: 1,
            dst: 2,
            tag: 3,
            kind: EventKind::External,
            data: None,
        };
        let _ = ev.take_data();
    }
}
