//! The simulation kernel: entity table + event loop.

use super::entity::{Ctx, Entity, LinkModel, NoDelay};
use super::event::{Event, EventKind, EntityId};
use super::queue::EventQueue;
use crate::network::FlowTable;
use std::collections::HashMap;
use std::sync::Arc;

/// Kernel limits / options.
#[derive(Debug, Clone)]
pub struct SimConfig {
    /// Hard stop: no event at `time > max_time` is dispatched. `f64::INFINITY`
    /// disables the limit.
    pub max_time: f64,
    /// Hard stop on number of dispatched events (runaway protection).
    pub max_events: u64,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig { max_time: f64::INFINITY, max_events: u64::MAX }
    }
}

/// The simulation: owns entities, the future-event queue, the clock and the
/// network model. Equivalent of SimJava's `Sim_system` plus GridSim's
/// `GridSim.Init()/Start()` lifecycle.
pub struct Simulation<M> {
    entities: Vec<Option<Box<dyn Entity<M>>>>,
    /// Entity names, interned once at [`add`](Self::add) as `Arc<str>` so
    /// diagnostics and per-event contexts share them without cloning the
    /// underlying bytes.
    names: Vec<Arc<str>>,
    by_name: HashMap<Arc<str>, EntityId>,
    queue: EventQueue<M>,
    clock: f64,
    link: Box<dyn LinkModel>,
    /// In-flight shared-bandwidth flows (empty unless the link model is a
    /// flow model; see `crate::network`).
    flows: FlowTable<M>,
    config: SimConfig,
    events_processed: u64,
    stopped: bool,
    /// `on_start` hooks have run (the start phase is idempotent).
    started: bool,
    /// `on_end` hooks have run (the end phase is idempotent).
    ended: bool,
    /// Observer invoked on every dispatched event (after the clock advances,
    /// before the destination entity handles it).
    observer: Option<Box<dyn FnMut(&Event<M>) + Send>>,
}

// The whole simulation stack is `Send` (entities, link model and observer
// all carry `Send` bounds), so simulations can migrate across the sweep
// engine's worker threads. Compile-time proof:
#[allow(dead_code)]
fn _assert_simulation_send<M: Send + 'static>(sim: Simulation<M>) -> impl Send {
    sim
}

impl<M: 'static> Default for Simulation<M> {
    fn default() -> Self {
        Self::new()
    }
}

impl<M: 'static> Simulation<M> {
    /// An empty simulation with default [`SimConfig`] (no time or event
    /// limits): no entities, empty queue, clock at 0.
    pub fn new() -> Self {
        Simulation {
            entities: Vec::new(),
            names: Vec::new(),
            by_name: HashMap::new(),
            queue: EventQueue::new(),
            clock: 0.0,
            link: Box::new(NoDelay),
            flows: FlowTable::new(),
            config: SimConfig::default(),
            events_processed: 0,
            stopped: false,
            started: false,
            ended: false,
            observer: None,
        }
    }

    /// [`new`](Self::new) with explicit kernel limits.
    pub fn with_config(config: SimConfig) -> Self {
        let mut s = Self::new();
        s.config = config;
        s
    }

    /// Install a network-delay model (see `gridsim::network`).
    pub fn set_link_model(&mut self, link: Box<dyn LinkModel>) {
        self.link = link;
    }

    /// Register an entity; returns its id. Names must be unique (the paper
    /// derives I/O entity names from entity names and requires uniqueness).
    pub fn add(&mut self, entity: Box<dyn Entity<M>>) -> EntityId {
        let name: Arc<str> = Arc::from(entity.name());
        assert!(
            !self.by_name.contains_key(&*name),
            "duplicate entity name {name:?}"
        );
        let id = self.entities.len();
        self.by_name.insert(name.clone(), id);
        self.names.push(name);
        self.entities.push(Some(entity));
        id
    }

    /// Look up an entity id by name.
    pub fn lookup(&self, name: &str) -> Option<EntityId> {
        self.by_name.get(name).copied()
    }

    /// Name of an entity (observer/diagnostics support).
    pub fn name_of(&self, id: EntityId) -> &str {
        &self.names[id]
    }

    /// Install an observer called for every dispatched event, after the
    /// clock advances to the event's timestamp and before the destination
    /// entity handles it. One observer at a time (last install wins).
    pub fn set_observer(&mut self, observer: Box<dyn FnMut(&Event<M>) + Send>) {
        self.observer = Some(observer);
    }

    /// Remove the installed observer, returning it.
    pub fn take_observer(&mut self) -> Option<Box<dyn FnMut(&Event<M>) + Send>> {
        self.observer.take()
    }

    /// Number of registered entities.
    pub fn entity_count(&self) -> usize {
        self.entities.len()
    }

    /// Current simulation clock.
    pub fn clock(&self) -> f64 {
        self.clock
    }

    /// Number of events dispatched so far. Under a flow model this includes
    /// `FlowWake` finish markers (live and stale) — they are kernel events,
    /// popped, counted and shown to the observer like any other.
    pub fn events_processed(&self) -> u64 {
        self.events_processed
    }

    /// Number of shared-bandwidth flows currently in flight (always 0 for
    /// scalar link models; see `crate::network`).
    pub fn active_flows(&self) -> usize {
        self.flows.len()
    }

    /// Timestamp of the next pending event, if any (lets pacing loops skip
    /// over gaps in a sparse queue instead of polling through them).
    pub fn next_event_time(&self) -> Option<f64> {
        self.queue.peek_time()
    }

    /// Borrow a concrete entity back out of the simulation (post-run
    /// inspection of results).
    pub fn get<T: 'static>(&self, id: EntityId) -> Option<&T> {
        self.entities[id].as_ref().and_then(|e| e.as_any().downcast_ref::<T>())
    }

    /// Mutable variant of [`get`](Self::get) (test fixtures, fault
    /// injection).
    pub fn get_mut<T: 'static>(&mut self, id: EntityId) -> Option<&mut T> {
        self.entities[id].as_mut().and_then(|e| e.as_any_mut().downcast_mut::<T>())
    }

    /// Start phase: `on_start` for every entity in id order. Idempotent —
    /// [`step`](Self::step)/[`run_until`](Self::run_until)/[`run`](Self::run)
    /// call it implicitly; explicit calls are allowed for observation before
    /// the first event.
    pub fn init(&mut self) {
        if self.started {
            return;
        }
        self.started = true;
        for id in 0..self.entities.len() {
            if self.stopped {
                break;
            }
            self.with_entity(id, |ent, ctx| ent.on_start(ctx));
        }
    }

    /// True when the event loop cannot dispatch any further event: an entity
    /// requested stop, a kernel limit was hit, or the queue is drained (or
    /// holds only events beyond `max_time`). A simulation whose start phase
    /// has not run yet is *not* idle — entities schedule their first events
    /// in `init()`, so `while !is_idle() { step()/run_until() }` works
    /// without an explicit `init()` call.
    pub fn is_idle(&self) -> bool {
        if !self.started {
            return false;
        }
        self.stopped
            || self.events_processed >= self.config.max_events
            || match self.queue.peek_time() {
                None => true,
                Some(t) => t > self.config.max_time,
            }
    }

    /// Dispatch exactly one event. Runs the start phase first if needed.
    /// Returns the dispatched event's timestamp, or `None` when the
    /// simulation is idle (see [`is_idle`](Self::is_idle)).
    pub fn step(&mut self) -> Option<f64> {
        self.step_before(f64::INFINITY)
    }

    /// Dispatch exactly one event whose timestamp is ≤ `horizon` (and ≤ the
    /// configured `max_time`). Runs the start phase first if needed. Returns
    /// the dispatched event's timestamp, or `None` when no due event exists
    /// or the simulation is idle.
    ///
    /// This is the kernel's single-comparison hot path: the horizon check
    /// happens inside [`EventQueue::pop_before`] on the heap root, so a
    /// bounded loop like [`run_until`](Self::run_until) costs one heap
    /// access per event instead of a peek-then-pop pair.
    pub fn step_before(&mut self, horizon: f64) -> Option<f64> {
        self.init();
        if self.stopped || self.events_processed >= self.config.max_events || horizon.is_nan() {
            return None;
        }
        let ev = self.queue.pop_before(horizon.min(self.config.max_time))?;
        debug_assert!(
            ev.time + 1e-9 >= self.clock,
            "time went backwards: {} -> {}",
            self.clock,
            ev.time
        );
        self.clock = ev.time.max(self.clock);
        self.events_processed += 1;
        if let Some(obs) = self.observer.as_mut() {
            obs(&ev);
        }
        let t = self.clock;
        if ev.kind == EventKind::FlowWake {
            self.flow_wake(ev);
        } else {
            let dst = ev.dst;
            self.dispatch(dst, ev);
        }
        Some(t)
    }

    /// Handle a popped flow finish marker: drop it when stale (a recompute
    /// superseded it), otherwise complete the flow — deliver its payload as
    /// an external event after the model's latency, release its link shares
    /// and reschedule every flow on the touched endpoints.
    fn flow_wake(&mut self, ev: Event<M>) {
        let id = ev.tag as u64;
        if !self.flows.is_live(id, ev.seq) {
            return;
        }
        let done = self.flows.complete(id);
        self.queue.push(Event {
            time: self.clock + self.link.flow_latency(),
            seq: 0, // assigned by the queue
            src: done.src,
            dst: done.dst,
            tag: done.tag,
            kind: EventKind::External,
            data: done.data,
        });
        self.flows.recompute(self.clock, done.src, done.dst, self.link.as_ref(), &mut self.queue);
    }

    /// Dispatch every event with timestamp ≤ `t`, then return the clock.
    /// The clock does *not* jump to `t` — it tracks the last dispatched
    /// event, so an incremental `run_until` sweep reaches exactly the same
    /// final clock as one [`run`](Self::run).
    pub fn run_until(&mut self, t: f64) -> f64 {
        while self.step_before(t).is_some() {}
        self.clock
    }

    /// End phase: `on_end` for every entity (reporting hooks). Idempotent.
    /// Returns the final clock.
    pub fn finalize(&mut self) -> f64 {
        self.init();
        if self.ended {
            return self.clock;
        }
        self.ended = true;
        for id in 0..self.entities.len() {
            self.with_entity(id, |ent, ctx| ent.on_end(ctx));
        }
        self.clock
    }

    /// Run the simulation to completion: `on_start` for every entity in id
    /// order, then the event loop until the queue drains, an entity calls
    /// [`Ctx::stop`], or a kernel limit is hit. Returns the final clock.
    ///
    /// Equivalent to `init()` + `step()` until idle + `finalize()` — the
    /// stepped API produces bit-identical results.
    pub fn run(&mut self) -> f64 {
        self.init();
        while self.step().is_some() {}
        self.finalize()
    }

    fn dispatch(&mut self, dst: EntityId, ev: Event<M>) {
        let mut ent = self.entities[dst]
            .take()
            .unwrap_or_else(|| panic!("entity {dst} re-entered (event to self mid-dispatch?)"));
        let mut ctx = Ctx {
            now: self.clock,
            me: dst,
            queue: &mut self.queue,
            link: self.link.as_ref(),
            flows: &mut self.flows,
            stop_requested: &mut self.stopped,
            names: &self.names,
        };
        ent.on_event(&mut ctx, ev);
        self.entities[dst] = Some(ent);
    }

    fn with_entity(&mut self, id: EntityId, f: impl FnOnce(&mut Box<dyn Entity<M>>, &mut Ctx<M>)) {
        let mut ent = self.entities[id].take().expect("entity missing");
        let mut ctx = Ctx {
            now: self.clock,
            me: id,
            queue: &mut self.queue,
            link: self.link.as_ref(),
            flows: &mut self.flows,
            stop_requested: &mut self.stopped,
            names: &self.names,
        };
        f(&mut ent, &mut ctx);
        self.entities[id] = Some(ent);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::des::event::EventKind;
    use std::any::Any;

    /// Ping-pong pair: A sends to B, B replies, N rounds.
    struct Ping {
        name: String,
        peer: EntityId,
        rounds_left: u32,
        log: Vec<f64>,
        start: bool,
    }

    impl Entity<u32> for Ping {
        fn name(&self) -> &str {
            &self.name
        }
        fn on_start(&mut self, ctx: &mut Ctx<u32>) {
            if self.start {
                ctx.send_delayed(self.peer, 1.0, 1, Some(self.rounds_left));
            }
        }
        fn on_event(&mut self, ctx: &mut Ctx<u32>, mut ev: Event<u32>) {
            self.log.push(ctx.now());
            let n = ev.take_data();
            if n == 0 {
                ctx.stop();
            } else {
                ctx.send_delayed(self.peer, 1.0, 1, Some(n - 1));
            }
        }
        fn as_any(&self) -> &dyn Any {
            self
        }
        fn as_any_mut(&mut self) -> &mut dyn Any {
            self
        }
    }

    fn ping(name: &str, peer: EntityId, rounds: u32, start: bool) -> Box<Ping> {
        Box::new(Ping { name: name.into(), peer, rounds_left: rounds, log: vec![], start })
    }

    #[test]
    fn ping_pong_advances_clock() {
        let mut sim = Simulation::new();
        let a = sim.add(ping("a", 1, 6, true));
        let b = sim.add(ping("b", 0, 0, false));
        let end = sim.run();
        assert_eq!(end, 7.0); // 7 hops of delay 1.0
        let pa = sim.get::<Ping>(a).unwrap();
        let pb = sim.get::<Ping>(b).unwrap();
        // b receives at t=1,3,5,7 ; a receives at t=2,4,6
        assert_eq!(pb.log, vec![1.0, 3.0, 5.0, 7.0]);
        assert_eq!(pa.log, vec![2.0, 4.0, 6.0]);
    }

    #[test]
    fn lookup_by_name() {
        let mut sim = Simulation::new();
        let a = sim.add(ping("alpha", 0, 0, false));
        assert_eq!(sim.lookup("alpha"), Some(a));
        assert_eq!(sim.lookup("beta"), None);
    }

    #[test]
    #[should_panic(expected = "duplicate entity name")]
    fn duplicate_names_rejected() {
        let mut sim = Simulation::new();
        sim.add(ping("x", 0, 0, false));
        sim.add(ping("x", 0, 0, false));
    }

    #[test]
    fn max_events_limit() {
        struct Loopy;
        impl Entity<u32> for Loopy {
            fn name(&self) -> &str {
                "loopy"
            }
            fn on_start(&mut self, ctx: &mut Ctx<u32>) {
                ctx.schedule_self(1.0, 0, None);
            }
            fn on_event(&mut self, ctx: &mut Ctx<u32>, _ev: Event<u32>) {
                ctx.schedule_self(1.0, 0, None);
            }
            fn as_any(&self) -> &dyn Any {
                self
            }
            fn as_any_mut(&mut self) -> &mut dyn Any {
                self
            }
        }
        let mut sim = Simulation::with_config(SimConfig { max_time: f64::INFINITY, max_events: 100 });
        sim.add(Box::new(Loopy));
        sim.run();
        assert_eq!(sim.events_processed(), 100);
    }

    #[test]
    fn max_time_limit() {
        struct Loopy;
        impl Entity<u32> for Loopy {
            fn name(&self) -> &str {
                "loopy"
            }
            fn on_start(&mut self, ctx: &mut Ctx<u32>) {
                ctx.schedule_self(1.0, 0, None);
            }
            fn on_event(&mut self, ctx: &mut Ctx<u32>, _ev: Event<u32>) {
                ctx.schedule_self(1.0, 0, None);
            }
            fn as_any(&self) -> &dyn Any {
                self
            }
            fn as_any_mut(&mut self) -> &mut dyn Any {
                self
            }
        }
        let mut sim = Simulation::with_config(SimConfig { max_time: 50.0, max_events: u64::MAX });
        sim.add(Box::new(Loopy));
        let end = sim.run();
        assert!(end <= 50.0);
    }

    #[test]
    fn internal_events_flagged() {
        struct SelfSched {
            saw_internal: bool,
        }
        impl Entity<u32> for SelfSched {
            fn name(&self) -> &str {
                "s"
            }
            fn on_start(&mut self, ctx: &mut Ctx<u32>) {
                ctx.schedule_self(2.0, 9, None);
            }
            fn on_event(&mut self, _ctx: &mut Ctx<u32>, ev: Event<u32>) {
                assert_eq!(ev.kind, EventKind::Internal);
                assert_eq!(ev.tag, 9);
                self.saw_internal = true;
            }
            fn as_any(&self) -> &dyn Any {
                self
            }
            fn as_any_mut(&mut self) -> &mut dyn Any {
                self
            }
        }
        let mut sim = Simulation::new();
        let id = sim.add(Box::new(SelfSched { saw_internal: false }));
        sim.run();
        assert!(sim.get::<SelfSched>(id).unwrap().saw_internal);
    }

    #[test]
    fn stepped_run_matches_run() {
        let build = || {
            let mut sim = Simulation::new();
            let a = sim.add(ping("a", 1, 6, true));
            let b = sim.add(ping("b", 0, 0, false));
            (sim, a, b)
        };
        let (mut whole, wa, _) = build();
        let end_whole = whole.run();

        let (mut stepped, sa, _) = build();
        stepped.init();
        let mut steps = 0;
        while stepped.step().is_some() {
            steps += 1;
        }
        let end_stepped = stepped.finalize();

        assert_eq!(end_whole.to_bits(), end_stepped.to_bits());
        assert_eq!(whole.events_processed(), stepped.events_processed());
        assert_eq!(steps, stepped.events_processed());
        assert_eq!(
            whole.get::<Ping>(wa).unwrap().log,
            stepped.get::<Ping>(sa).unwrap().log
        );
    }

    #[test]
    fn run_until_dispatches_only_due_events() {
        let mut sim = Simulation::new();
        let a = sim.add(ping("a", 1, 6, true));
        let b = sim.add(ping("b", 0, 0, false));
        // b receives at t=1,3,5,7 ; a receives at t=2,4,6.
        let clock = sim.run_until(3.5);
        assert_eq!(clock, 3.0, "clock tracks the last dispatched event");
        assert_eq!(sim.get::<Ping>(b).unwrap().log, vec![1.0, 3.0]);
        assert_eq!(sim.get::<Ping>(a).unwrap().log, vec![2.0]);
        assert!(!sim.is_idle());
        // Resume in increments; the tail matches a whole run.
        sim.run_until(5.0);
        sim.run_until(1e9);
        assert!(sim.is_idle());
        assert_eq!(sim.finalize(), 7.0);
        assert_eq!(sim.get::<Ping>(b).unwrap().log, vec![1.0, 3.0, 5.0, 7.0]);
    }

    #[test]
    fn fresh_simulation_is_not_idle() {
        // Before init() the start phase is pending, so an is_idle-driven
        // loop must enter its body (step/run_until init implicitly).
        let mut sim = Simulation::new();
        sim.add(ping("a", 1, 2, true));
        sim.add(ping("b", 0, 0, false));
        assert!(!sim.is_idle());
        let mut horizon = 0.0;
        while !sim.is_idle() {
            horizon += 1.0;
            sim.run_until(horizon);
        }
        assert_eq!(sim.finalize(), 3.0); // 3 hops of delay 1.0
    }

    #[test]
    fn init_and_finalize_are_idempotent() {
        let mut sim = Simulation::new();
        sim.add(ping("a", 1, 2, true));
        sim.add(ping("b", 0, 0, false));
        sim.init();
        sim.init();
        let events_after_init = sim.events_processed();
        assert_eq!(events_after_init, 0, "init dispatches nothing");
        sim.run_until(1e9);
        let end = sim.finalize();
        assert_eq!(sim.finalize(), end, "finalize is stable");
    }

    #[test]
    fn observer_sees_every_event() {
        use std::sync::{Arc, Mutex};
        let seen: Arc<Mutex<Vec<(f64, EntityId)>>> = Arc::new(Mutex::new(vec![]));
        let sink = seen.clone();
        let mut sim = Simulation::new();
        sim.add(ping("a", 1, 2, true));
        sim.add(ping("b", 0, 0, false));
        sim.set_observer(Box::new(move |ev: &Event<u32>| {
            sink.lock().unwrap().push((ev.time, ev.dst));
        }));
        sim.run();
        let seen = seen.lock().unwrap();
        assert_eq!(seen.len() as u64, sim.events_processed());
        assert_eq!(seen[0], (1.0, 1));
        assert!(seen.windows(2).all(|w| w[0].0 <= w[1].0), "observer sees time order");
    }
}
