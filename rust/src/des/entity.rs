//! Entities and the context handed to them on every event.

use super::event::{Event, EventKind};
use super::queue::EventQueue;
use crate::network::FlowTable;
use std::any::Any;
use std::sync::Arc;

pub use super::event::EntityId;

/// Network-delay model consulted on every [`Ctx::send`].
///
/// The paper routes every message through per-entity `Input`/`Output`
/// entities that add a transfer delay of `bytes / baud_rate` (plus queueing).
/// We preserve the observable delay semantics by asking this model for the
/// delivery delay of each send; `gridsim::network` implements the paper's
/// baud-rate model on top of this hook.
///
/// `Send` so a whole [`crate::des::Simulation`] can move between threads
/// (the sweep engine runs one simulation per worker).
pub trait LinkModel: Send {
    /// Delay (simulation time units) for `bytes` from `src` to `dst`.
    ///
    /// For flow models ([`is_flow`](Self::is_flow) true) this is only the
    /// zero-contention fallback, used for payload-free control messages;
    /// sized transfers go through the kernel's [`FlowTable`] instead.
    fn delay(&self, src: EntityId, dst: EntityId, bytes: u64) -> f64;

    /// True when this model tracks per-flow shared-bandwidth state. The
    /// kernel then routes every sized [`Ctx::send`] through its
    /// [`FlowTable`]: concurrent transfers fair-share link capacity and
    /// their finish events are rescheduled on every flow start/finish.
    /// Scalar models (the default) keep the closed-form delay path.
    fn is_flow(&self) -> bool {
        false
    }

    /// Fixed per-message latency a flow model adds after a transfer
    /// completes (the propagation-delay counterpart of the baud model's
    /// additive latency). Only consulted when [`is_flow`](Self::is_flow)
    /// is true.
    fn flow_latency(&self) -> f64 {
        0.0
    }

    /// Access-link capacity of entity `e` in bits per simulation time
    /// unit. A flow `src → dst` occupies both endpoints' access links and
    /// progresses at `min(cap(src)/n(src), cap(dst)/n(dst))` where `n` is
    /// the number of flows currently using each link. Only consulted when
    /// [`is_flow`](Self::is_flow) is true; implementations must return
    /// finite positive capacities.
    fn capacity_of(&self, _e: EntityId) -> f64 {
        f64::INFINITY
    }
}

/// Zero-delay network (direct delivery).
pub struct NoDelay;

impl LinkModel for NoDelay {
    fn delay(&self, _src: EntityId, _dst: EntityId, _bytes: u64) -> f64 {
        0.0
    }
}

/// Per-event context: the only capability surface an entity has during
/// `on_event`. It can read the clock, send events (through the network
/// model), schedule internal events on itself, and request simulation stop.
pub struct Ctx<'a, M> {
    pub(crate) now: f64,
    pub(crate) me: EntityId,
    pub(crate) queue: &'a mut EventQueue<M>,
    pub(crate) link: &'a dyn LinkModel,
    pub(crate) flows: &'a mut FlowTable<M>,
    pub(crate) stop_requested: &'a mut bool,
    pub(crate) names: &'a [Arc<str>],
}

impl<'a, M> Ctx<'a, M> {
    /// Current simulation time.
    pub fn now(&self) -> f64 {
        self.now
    }

    /// Id of the entity currently handling an event.
    pub fn me(&self) -> EntityId {
        self.me
    }

    /// Name of an entity (diagnostics).
    pub fn name_of(&self, id: EntityId) -> &str {
        &self.names[id]
    }

    /// Number of entities in the simulation.
    pub fn entity_count(&self) -> usize {
        self.names.len()
    }

    /// Send an event through the simulated network: delivery is delayed by
    /// the link model according to the payload size in bytes.
    ///
    /// Under a flow model ([`LinkModel::is_flow`]) a sized send to another
    /// entity becomes a *flow*: the transfer fair-shares both endpoints'
    /// link capacity with every concurrent flow, and delivery happens when
    /// the (contention-dependent) transfer completes. Payload-free sends
    /// and self-sends keep the closed-form delay path under every model.
    pub fn send(&mut self, dst: EntityId, tag: i64, data: Option<M>, bytes: u64) -> u64 {
        if self.link.is_flow() && dst != self.me && bytes > 0 {
            assert!(dst < self.names.len(), "send to unknown entity id {dst}");
            return self.flows.begin(self.now, self.me, dst, tag, data, bytes, self.link, self.queue);
        }
        let delay = self.link.delay(self.me, dst, bytes);
        debug_assert!(delay >= 0.0);
        self.push(dst, delay, tag, data, EventKind::External)
    }

    /// Send an event with an explicit delay, bypassing the network model
    /// (control-plane messages; the paper's `sim_schedule` with delay).
    pub fn send_delayed(&mut self, dst: EntityId, delay: f64, tag: i64, data: Option<M>) -> u64 {
        self.push(dst, delay, tag, data, EventKind::External)
    }

    /// Schedule an *internal* event on the current entity after `delay`.
    ///
    /// Returns the event's unique sequence number. Entities implementing the
    /// paper's stale-interrupt rule (Figs 7/10: "if the event is internal and
    /// its tag value is the same as the recently scheduled event") remember
    /// this id and compare it against [`Event::seq`] on receipt.
    pub fn schedule_self(&mut self, delay: f64, tag: i64, data: Option<M>) -> u64 {
        self.push(self.me, delay, tag, data, EventKind::Internal)
    }

    /// Request an orderly end of the simulation: the event loop stops after
    /// the current event (the paper's `END_OF_SIMULATION` broadcast).
    pub fn stop(&mut self) {
        *self.stop_requested = true;
    }

    fn push(&mut self, dst: EntityId, delay: f64, tag: i64, data: Option<M>, kind: EventKind) -> u64 {
        assert!(dst < self.names.len(), "send to unknown entity id {dst}");
        self.queue.push(Event {
            time: self.now + delay,
            seq: 0, // assigned by the queue
            src: self.me,
            dst,
            tag,
            kind,
            data,
        })
    }
}

/// Test support: build a [`Ctx`] outside the kernel so entity handlers can be
/// unit-tested in isolation (zero-delay link model, empty flow table).
pub fn test_ctx<'a, M>(
    now: f64,
    me: EntityId,
    queue: &'a mut EventQueue<M>,
    flows: &'a mut FlowTable<M>,
    stop: &'a mut bool,
    names: &'a [Arc<str>],
) -> Ctx<'a, M> {
    static NO_DELAY: NoDelay = NoDelay;
    Ctx { now, me, queue, link: &NO_DELAY, flows, stop_requested: stop, names }
}

/// A simulation entity. The `on_event` handler is the event-model equivalent
/// of SimJava's `body()` loop: it is invoked once per delivered event and may
/// mutate entity state, send events, and schedule internal interrupts.
///
/// Entities are `Send`: the whole simulation stack is migratable between
/// threads, which is what lets the sweep engine run independent scenario
/// cells on a worker pool.
pub trait Entity<M>: Any + Send {
    /// Unique entity name (the paper identifies entities by name).
    fn name(&self) -> &str;

    /// Called once at simulation start (time 0), in entity-id order. This is
    /// where resources register with the information service, users kick off
    /// experiments, etc.
    fn on_start(&mut self, _ctx: &mut Ctx<M>) {}

    /// Handle one delivered event.
    fn on_event(&mut self, ctx: &mut Ctx<M>, ev: Event<M>);

    /// Called once after the event loop terminates (reporting hooks).
    fn on_end(&mut self, _ctx: &mut Ctx<M>) {}

    /// Downcasting support so callers can retrieve concrete entity state
    /// after a run (e.g. a user's completed-gridlet statistics).
    fn as_any(&self) -> &dyn Any;

    /// Mutable counterpart of [`as_any`](Self::as_any) (post-run mutation,
    /// test fixtures).
    fn as_any_mut(&mut self) -> &mut dyn Any;
}
