//! Runtime for the AOT-compiled L1/L2 numeric kernels.
//!
//! The DBC schedule advisor (paper Fig 20 steps a–c) and the time-shared
//! completion forecaster (Fig 8) are expressed as fixed-shape tensor programs
//! in `python/compile/` (JAX + Pallas), lowered once to HLO text by
//! `make artifacts`, and executed here through the PJRT CPU client of the
//! `xla` crate. [`native`] mirrors the same math in pure Rust — it is both
//! the no-artifacts fallback and the differential-testing oracle for the XLA
//! path.

pub mod advisor;
pub mod native;
pub mod pjrt;

pub use advisor::{Advisor, AdvisorInput, ResourceSnapshot};
pub use native::NativeAdvisor;
pub use pjrt::{forecast_shapes, ForecastInput, PjrtRuntime, XlaAdvisor, XlaForecaster, ADVISOR_R};
