//! Advisor interface: the broker's per-tick allocation decision
//! (paper Fig 20, SCHEDULE ADVISOR steps a–c) as a pure function.
//!
//! Given, per resource, the measured/extrapolated MI consumption rate and
//! the cost per MI, plus the remaining deadline/budget and the job pool,
//! produce the desired number of jobs allocated to each resource.
//!
//! **Precondition**: `resources` are sorted by ascending `cost_per_mi`.
//! (The paper's step 4 — "SORT resources by increasing order of cost" — is
//! done once by the broker; both the native and the XLA advisor exploit it:
//! greedy budget truncation over a cost-sorted list is exactly computable
//! with prefix sums, because once the budget truncates resource *k*, the
//! leftover is smaller than the per-job cost of every later resource.)

/// Per-resource snapshot fed to the advisor.
#[derive(Debug, Clone)]
pub struct ResourceSnapshot {
    /// Measured (or initially optimistic) MI/time available to this user.
    pub rate_mi: f64,
    /// G$ per MI on this resource.
    pub cost_per_mi: f64,
}

/// Advisor input: the broker state relevant to one allocation decision.
#[derive(Debug, Clone)]
pub struct AdvisorInput {
    /// Snapshots sorted by ascending `cost_per_mi`.
    pub resources: Vec<ResourceSnapshot>,
    /// Time remaining until the absolute deadline.
    pub time_left: f64,
    /// Budget remaining (absolute budget − spent − committed estimate).
    pub budget_left: f64,
    /// Mean job length in MI (capacity quantum).
    pub avg_job_mi: f64,
    /// Jobs to place (unassigned + currently assigned; the advisor re-plans
    /// the full pool every tick).
    pub jobs: usize,
}

impl AdvisorInput {
    /// Sanity-check the cost-sorted precondition (debug builds / tests).
    pub fn is_cost_sorted(&self) -> bool {
        self.resources.windows(2).all(|w| w[0].cost_per_mi <= w[1].cost_per_mi)
    }
}

/// An allocation engine. Implementations: [`super::NativeAdvisor`] (pure
/// Rust) and [`super::XlaAdvisor`] (AOT JAX/Pallas artifact via PJRT).
///
/// `Send` so brokers (and the sessions holding them) can move between the
/// sweep engine's worker threads.
pub trait Advisor: Send {
    /// Desired job count per resource, aligned with `input.resources`.
    /// The sum is ≤ `input.jobs`; allocations respect per-resource deadline
    /// capacity and the global budget.
    fn advise(&mut self, input: &AdvisorInput) -> Vec<usize>;

    /// Implementation name for logs/benches.
    fn name(&self) -> &'static str;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sorted_precondition_check() {
        let input = AdvisorInput {
            resources: vec![
                ResourceSnapshot { rate_mi: 1.0, cost_per_mi: 0.1 },
                ResourceSnapshot { rate_mi: 1.0, cost_per_mi: 0.2 },
            ],
            time_left: 1.0,
            budget_left: 1.0,
            avg_job_mi: 1.0,
            jobs: 1,
        };
        assert!(input.is_cost_sorted());
        let mut bad = input.clone();
        bad.resources.reverse();
        assert!(!bad.is_cost_sorted());
    }
}
