//! PJRT runtime: load the AOT-compiled HLO-text artifacts produced by
//! `make artifacts` (python/compile/aot.py) and execute them on the CPU
//! PJRT client.
//!
//! Interchange is HLO **text** — the image's xla_extension 0.5.1 rejects
//! jax ≥ 0.5 serialized `HloModuleProto`s (64-bit instruction ids); the text
//! parser reassigns ids. Each artifact is compiled once at load time; only
//! `execute` runs on the broker hot path.
//!
//! The whole PJRT path is gated behind the `xla` cargo feature because the
//! `xla` bindings crate is not vendored in this tree. Without the feature,
//! the same public types exist but their loaders return a descriptive error,
//! so callers (CLI `--advisor xla`, differential tests, benches) degrade
//! gracefully instead of failing to compile.

use super::advisor::{Advisor, AdvisorInput};
use std::path::Path;

/// Fixed resource-axis padding of the advisor artifact. Must match
/// `python/compile/model.py::R`.
pub const ADVISOR_R: usize = 16;
/// Fixed shapes of the forecast artifact `[R, J]`. Must match
/// `python/compile/model.py::FORECAST_R/J`.
pub const FORECAST_R: usize = 16;
/// Fixed job-axis padding of the forecast artifact (columns of `[R, J]`).
pub const FORECAST_J: usize = 256;

/// `(rows, cols)` of the forecast artifact.
pub fn forecast_shapes() -> (usize, usize) {
    (FORECAST_R, FORECAST_J)
}

/// Input to the batched time-shared completion forecaster
/// (`artifacts/forecast.hlo.txt`), padded to `[FORECAST_R, FORECAST_J]`.
#[derive(Debug, Clone)]
pub struct ForecastInput {
    /// Remaining MI per (resource, job slot); 0 for inactive slots.
    pub remaining_mi: Vec<Vec<f64>>,
    /// Per-resource MIPS of one PE.
    pub mips_per_pe: Vec<f64>,
    /// Per-resource PE count.
    pub num_pe: Vec<usize>,
    /// Per-resource availability factor (1 − local load).
    pub availability: Vec<f64>,
}

#[cfg(not(feature = "xla"))]
const NO_XLA: &str = "gridsim was built without the `xla` cargo feature; the PJRT \
     advisor/forecaster path is unavailable (rebuild with `--features xla` and the \
     xla bindings crate, or use the native advisor)";

/// A compiled HLO artifact on the CPU PJRT client.
///
/// `Advisor: Send` (the sweep engine moves advisors across worker threads),
/// so a feature-on build requires `PjrtRuntime: Send`. We deliberately do
/// NOT assert that with an `unsafe impl` here: the bindings are not
/// vendored, so their thread-safety cannot be audited in-tree. If the
/// `xla::PjRtLoadedExecutable` wrapper is not `Send`, the build fails at
/// `impl Advisor for XlaAdvisor` — audit the bindings and add the impl
/// there, rather than discovering a data race under `sweep --jobs N`.
#[cfg(feature = "xla")]
pub struct PjrtRuntime {
    exe: xla::PjRtLoadedExecutable,
}

/// Stub: the crate was built without the `xla` feature.
#[cfg(not(feature = "xla"))]
pub struct PjrtRuntime {
    _private: (),
}

#[cfg(feature = "xla")]
impl PjrtRuntime {
    /// Load HLO text from `path`, compile it on a fresh CPU client.
    pub fn load(path: &Path) -> anyhow::Result<PjrtRuntime> {
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow::anyhow!("PJRT CPU client: {e:?}"))?;
        let proto = xla::HloModuleProto::from_text_file(path)
            .map_err(|e| anyhow::anyhow!("parse HLO text {path:?}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compile {path:?}: {e:?}"))?;
        Ok(PjrtRuntime { exe })
    }

    /// Execute with literal inputs; returns the flattened tuple outputs.
    pub fn execute(&self, inputs: &[xla::Literal]) -> anyhow::Result<Vec<xla::Literal>> {
        let result = self
            .exe
            .execute::<xla::Literal>(inputs)
            .map_err(|e| anyhow::anyhow!("execute: {e:?}"))?;
        let out = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetch result: {e:?}"))?;
        out.to_tuple().map_err(|e| anyhow::anyhow!("untuple: {e:?}"))
    }
}

#[cfg(not(feature = "xla"))]
impl PjrtRuntime {
    /// Stub loader: always errs, describing how to enable the `xla` feature.
    pub fn load(_path: &Path) -> anyhow::Result<PjrtRuntime> {
        Err(anyhow::anyhow!(NO_XLA))
    }
}

#[cfg(feature = "xla")]
fn f32_vec(xs: &[f32]) -> xla::Literal {
    xla::Literal::vec1(xs)
}

#[cfg(feature = "xla")]
fn f32_scalar(x: f32) -> xla::Literal {
    xla::Literal::from(x)
}

/// The DBC cost-optimization schedule advisor backed by the
/// `artifacts/advisor.hlo.txt` artifact (Pallas kernel under the hood).
pub struct XlaAdvisor {
    #[cfg_attr(not(feature = "xla"), allow(dead_code))]
    runtime: PjrtRuntime,
}

impl XlaAdvisor {
    /// Load `advisor.hlo.txt` from an artifacts directory.
    pub fn load_dir(dir: &Path) -> anyhow::Result<XlaAdvisor> {
        Self::load(&dir.join("advisor.hlo.txt"))
    }

    /// Load and compile the advisor artifact at an explicit path.
    pub fn load(path: &Path) -> anyhow::Result<XlaAdvisor> {
        Ok(XlaAdvisor { runtime: PjrtRuntime::load(path)? })
    }

    /// Default artifacts location (repo-root `artifacts/`), if present.
    pub fn load_default() -> anyhow::Result<XlaAdvisor> {
        Self::load_dir(Path::new("artifacts"))
    }
}

#[cfg(feature = "xla")]
impl Advisor for XlaAdvisor {
    fn advise(&mut self, input: &AdvisorInput) -> Vec<usize> {
        debug_assert!(input.is_cost_sorted(), "advisor requires cost-sorted resources");
        let n = input.resources.len();
        assert!(
            n <= ADVISOR_R,
            "XLA advisor artifact is compiled for ≤{ADVISOR_R} resources, got {n}"
        );
        let mut rate = [0f32; ADVISOR_R];
        let mut cost = [0f32; ADVISOR_R];
        let mut active = [0f32; ADVISOR_R];
        for (i, s) in input.resources.iter().enumerate() {
            rate[i] = s.rate_mi as f32;
            cost[i] = s.cost_per_mi as f32;
            active[i] = 1.0;
        }
        let inputs = [
            f32_vec(&rate),
            f32_vec(&cost),
            f32_vec(&active),
            f32_scalar(input.time_left.max(0.0) as f32),
            f32_scalar(input.budget_left.max(0.0) as f32),
            f32_scalar(input.avg_job_mi as f32),
            f32_scalar(input.jobs as f32),
        ];
        let outputs = self
            .runtime
            .execute(&inputs)
            .expect("advisor artifact execution failed");
        let counts: Vec<f32> = outputs[0].to_vec().expect("advisor output not f32");
        counts[..n].iter().map(|&c| c.round().max(0.0) as usize).collect()
    }

    fn name(&self) -> &'static str {
        "xla"
    }
}

#[cfg(not(feature = "xla"))]
impl Advisor for XlaAdvisor {
    fn advise(&mut self, _input: &AdvisorInput) -> Vec<usize> {
        // `load` always errs without the feature, so no instance can exist.
        unreachable!("{NO_XLA}")
    }

    fn name(&self) -> &'static str {
        "xla"
    }
}

/// Batched forecaster backed by the forecast artifact.
pub struct XlaForecaster {
    #[cfg_attr(not(feature = "xla"), allow(dead_code))]
    runtime: PjrtRuntime,
}

impl XlaForecaster {
    /// Load `forecast.hlo.txt` from an artifacts directory.
    pub fn load_dir(dir: &Path) -> anyhow::Result<XlaForecaster> {
        Ok(XlaForecaster { runtime: PjrtRuntime::load(&dir.join("forecast.hlo.txt"))? })
    }
}

#[cfg(feature = "xla")]
impl XlaForecaster {
    /// Completion-time forecast per (resource, job); `None` for empty slots.
    /// Returns a dense `[R][J]` matrix of times (relative to now), with
    /// `f64::INFINITY` in inactive slots.
    pub fn forecast(&mut self, input: &ForecastInput) -> anyhow::Result<Vec<Vec<f64>>> {
        let r_used = input.remaining_mi.len();
        assert!(r_used <= FORECAST_R);
        let mut remaining = vec![0f32; FORECAST_R * FORECAST_J];
        let mut active = vec![0f32; FORECAST_R * FORECAST_J];
        let mut mips = [0f32; FORECAST_R];
        let mut pes = [1f32; FORECAST_R];
        let mut avail = [1f32; FORECAST_R];
        for (r, row) in input.remaining_mi.iter().enumerate() {
            assert!(row.len() <= FORECAST_J);
            for (j, &mi) in row.iter().enumerate() {
                if mi > 0.0 {
                    remaining[r * FORECAST_J + j] = mi as f32;
                    active[r * FORECAST_J + j] = 1.0;
                }
            }
            mips[r] = input.mips_per_pe[r] as f32;
            pes[r] = input.num_pe[r] as f32;
            avail[r] = input.availability[r] as f32;
        }
        let dims = [FORECAST_R as i64, FORECAST_J as i64];
        let inputs = [
            xla::Literal::vec1(&remaining)
                .reshape(&dims)
                .map_err(|e| anyhow::anyhow!("{e:?}"))?,
            xla::Literal::vec1(&active)
                .reshape(&dims)
                .map_err(|e| anyhow::anyhow!("{e:?}"))?,
            f32_vec(&mips),
            f32_vec(&pes),
            f32_vec(&avail),
        ];
        let outputs = self.runtime.execute(&inputs)?;
        let completion: Vec<f32> = outputs[0].to_vec().map_err(|e| anyhow::anyhow!("{e:?}"))?;
        let mut out = Vec::with_capacity(r_used);
        for r in 0..r_used {
            let cols = input.remaining_mi[r].len();
            out.push(
                (0..cols)
                    .map(|j| {
                        let v = completion[r * FORECAST_J + j] as f64;
                        if active[r * FORECAST_J + j] > 0.0 {
                            v
                        } else {
                            f64::INFINITY
                        }
                    })
                    .collect(),
            );
        }
        Ok(out)
    }
}

#[cfg(not(feature = "xla"))]
impl XlaForecaster {
    /// Stub: unreachable because `load_dir` always errs without the feature.
    pub fn forecast(&mut self, _input: &ForecastInput) -> anyhow::Result<Vec<Vec<f64>>> {
        unreachable!("{NO_XLA}")
    }
}

#[cfg(test)]
mod tests {
    // The XLA-backed paths need `artifacts/*.hlo.txt`; they are exercised by
    // `rust/tests/xla_advisor.rs` (integration) which skips gracefully when
    // artifacts have not been built yet.
    use super::*;

    #[test]
    fn shape_constants_consistent() {
        assert_eq!(forecast_shapes(), (FORECAST_R, FORECAST_J));
        assert!(ADVISOR_R >= 11, "must fit the 11-resource WWG testbed");
    }

    #[cfg(not(feature = "xla"))]
    #[test]
    fn stub_load_reports_missing_feature() {
        let err = XlaAdvisor::load_default().unwrap_err().to_string();
        assert!(err.contains("xla"), "{err}");
    }
}
