//! Pure-Rust reference advisor: the sequential greedy of paper Fig 20.
//!
//! Walk resources cheapest-first; each takes as many jobs as it can finish
//! by the deadline (measured rate × time ÷ mean job size), capped by the
//! jobs still unplaced and by what the remaining budget affords.

use super::advisor::{Advisor, AdvisorInput};

/// Sequential greedy DBC cost-optimization allocator.
#[derive(Debug, Default, Clone)]
pub struct NativeAdvisor;

impl NativeAdvisor {
    /// The advisor is stateless; `new()` exists for symmetry with loaders.
    pub fn new() -> NativeAdvisor {
        NativeAdvisor
    }
}

impl Advisor for NativeAdvisor {
    fn advise(&mut self, input: &AdvisorInput) -> Vec<usize> {
        debug_assert!(input.is_cost_sorted(), "advisor requires cost-sorted resources");
        let mut remaining_jobs = input.jobs;
        let mut remaining_budget = input.budget_left.max(0.0);
        let avg = input.avg_job_mi.max(1e-9);
        let time = input.time_left.max(0.0);
        let mut out = Vec::with_capacity(input.resources.len());
        for snap in &input.resources {
            // Step b: jobs this resource can complete by the deadline.
            let capacity = ((snap.rate_mi.max(0.0) * time) / avg * (1.0 + 1e-12) + 1e-9).floor() as usize;
            // Budget cap: whole jobs affordable at this resource's price.
            let cost_per_job = snap.cost_per_mi * avg;
            let affordable = if cost_per_job <= 0.0 {
                usize::MAX
            } else {
                // Relative epsilon: with B-factor = 1 budgets, the remaining
                // budget equals the remaining cost bit-for-bit only in exact
                // arithmetic; don't let 0.999999… floor to zero.
                (remaining_budget / cost_per_job * (1.0 + 1e-12) + 1e-9).floor() as usize
            };
            let n = capacity.min(remaining_jobs).min(affordable);
            out.push(n);
            remaining_jobs -= n;
            remaining_budget -= n as f64 * cost_per_job;
            if remaining_jobs == 0 {
                break;
            }
        }
        out.resize(input.resources.len(), 0);
        out
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::advisor::ResourceSnapshot;

    fn snap(rate: f64, cost: f64) -> ResourceSnapshot {
        ResourceSnapshot { rate_mi: rate, cost_per_mi: cost }
    }

    fn input(
        resources: Vec<ResourceSnapshot>,
        time: f64,
        budget: f64,
        avg: f64,
        jobs: usize,
    ) -> AdvisorInput {
        AdvisorInput { resources, time_left: time, budget_left: budget, avg_job_mi: avg, jobs }
    }

    #[test]
    fn cheapest_first_fills_to_capacity() {
        // Cheap resource can do 5 jobs, expensive can do 100; 8 jobs total.
        let inp = input(
            vec![snap(50.0, 0.01), snap(1000.0, 0.05)],
            10.0,
            1e9,
            100.0,
            8,
        );
        let alloc = NativeAdvisor::new().advise(&inp);
        assert_eq!(alloc, vec![5, 3]);
    }

    #[test]
    fn budget_truncates_expensive_tail() {
        // Cheap: capacity 2 (cost 1/job). Expensive: plenty capacity at
        // 10/job. Budget 25 → 2 cheap + 2 expensive (cost 2+20=22; a third
        // expensive job would need 32).
        let inp = input(
            vec![snap(20.0, 0.01), snap(1000.0, 0.10)],
            10.0,
            25.0,
            100.0,
            50,
        );
        let alloc = NativeAdvisor::new().advise(&inp);
        assert_eq!(alloc, vec![2, 2]);
    }

    #[test]
    fn no_time_no_jobs() {
        let inp = input(vec![snap(100.0, 0.01)], 0.0, 1e9, 100.0, 10);
        assert_eq!(NativeAdvisor::new().advise(&inp), vec![0]);
    }

    #[test]
    fn no_budget_no_jobs() {
        let inp = input(vec![snap(100.0, 0.01)], 10.0, 0.0, 100.0, 10);
        assert_eq!(NativeAdvisor::new().advise(&inp), vec![0]);
    }

    #[test]
    fn zero_cost_resource_unbounded_by_budget() {
        let inp = input(vec![snap(100.0, 0.0)], 10.0, 0.0, 100.0, 7);
        assert_eq!(NativeAdvisor::new().advise(&inp), vec![7]);
    }

    #[test]
    fn sum_never_exceeds_jobs() {
        let inp = input(
            vec![snap(1e6, 0.01), snap(1e6, 0.02), snap(1e6, 0.03)],
            100.0,
            1e12,
            100.0,
            13,
        );
        let alloc = NativeAdvisor::new().advise(&inp);
        assert_eq!(alloc.iter().sum::<usize>(), 13);
        assert_eq!(alloc, vec![13, 0, 0], "cheapest takes all when it can");
    }

    #[test]
    fn empty_resources() {
        let inp = input(vec![], 10.0, 10.0, 100.0, 5);
        assert!(NativeAdvisor::new().advise(&inp).is_empty());
    }
}
