//! The fault-injector entity: walks each resource's failure–repair process
//! and delivers `RESOURCE_FAIL`/`RESOURCE_RECOVER` at the sampled times.
//!
//! Event flow: at simulation start the injector samples each faulted
//! resource's first uptime (or reads its first trace interval) and schedules
//! one internal [`tags::FAULT_TICK`] per resource, carrying the resource's
//! index as a [`Msg::Control`] payload. Each tick delivers the pending
//! transition to the resource via `send_delayed` — control-plane, so fault
//! times are *never* distorted by the network model — then samples the next
//! transition and re-arms itself. Exactly one pending self-event per
//! resource keeps queue growth bounded; when the shutdown entity stops the
//! kernel, the injector's tail events simply die with the queue.

use super::{weibull, FaultProcess, FaultsSpec, FAULT_SEED_SALT};
use crate::des::{Ctx, EntityId, Event};
use crate::gridsim::messages::Msg;
use crate::gridsim::tags;
use crate::util::rng::Rng;

/// Per-resource process state.
#[derive(Debug)]
struct ProcessState {
    /// Resource entity the events are delivered to.
    target: EntityId,
    process: FaultProcess,
    /// Uptime multiplier (see [`FaultsSpec::mtbf_scaling`]).
    scaling: f64,
    rng: Rng,
    /// Current availability (true until the first failure fires).
    up: bool,
    /// Tag the armed `FAULT_TICK` will deliver to the resource.
    pending: i64,
    /// Next unconsumed `Trace` interval index.
    next_interval: usize,
}

impl ProcessState {
    /// Advance one transition: the delay from `now` to the next state flip
    /// and the resource-facing tag to deliver then. `None` when the process
    /// is exhausted (a `Trace` past its last interval).
    fn step(&mut self, now: f64) -> Option<(f64, i64)> {
        if self.up {
            let delay = match &self.process {
                FaultProcess::Exponential { mtbf, .. } => {
                    self.rng.exponential(mtbf * self.scaling)
                }
                FaultProcess::Weibull { mtbf, shape, .. } => {
                    weibull(&mut self.rng, mtbf * self.scaling, *shape)
                }
                FaultProcess::Trace { intervals } => {
                    let (start, _) = *intervals.get(self.next_interval)?;
                    (start * self.scaling - now).max(0.0)
                }
            };
            self.up = false;
            Some((delay, tags::RESOURCE_FAIL))
        } else {
            let delay = match &self.process {
                FaultProcess::Exponential { mttr, .. }
                | FaultProcess::Weibull { mttr, .. } => self.rng.exponential(*mttr),
                FaultProcess::Trace { intervals } => {
                    // Scaling shifts the failure onset but preserves the
                    // repair duration.
                    let (start, end) = intervals[self.next_interval];
                    self.next_interval += 1;
                    end - start
                }
            };
            self.up = true;
            Some((delay, tags::RESOURCE_RECOVER))
        }
    }
}

/// DES entity driving every configured failure–repair process.
///
/// Built by the session only when the scenario carries a
/// [`FaultsSpec`]; scenarios without one get no injector entity at all, so
/// their event streams (and reports) are byte-identical to a build without
/// this subsystem.
pub struct FaultInjector {
    name: String,
    states: Vec<ProcessState>,
}

impl FaultInjector {
    /// Build the injector for `spec` over `resources` — the scenario's
    /// resource list as `(entity_id, name)` pairs, in resource-index order.
    /// Resources whose name resolves to no process are skipped entirely.
    ///
    /// `seed` is the scenario seed: each resource's sampler derives a
    /// dedicated stream `Rng::new(seed ^ FAULT_SEED_SALT).derive(index)`,
    /// independent of the per-user workload streams.
    pub fn new(spec: &FaultsSpec, resources: &[(EntityId, String)], seed: u64) -> FaultInjector {
        let root = Rng::new(seed ^ FAULT_SEED_SALT);
        let states = resources
            .iter()
            .enumerate()
            .filter_map(|(k, (id, name))| {
                spec.process_for(name).map(|p| ProcessState {
                    target: *id,
                    process: p.clone(),
                    scaling: spec.mtbf_scaling,
                    rng: root.derive(k as u64),
                    up: true,
                    pending: tags::INSIGNIFICANT,
                    next_interval: 0,
                })
            })
            .collect();
        FaultInjector { name: "FaultInjector".into(), states }
    }

    /// Number of resources with an active failure–repair process.
    pub fn driven(&self) -> usize {
        self.states.len()
    }

    fn arm(state: &mut ProcessState, k: usize, ctx: &mut Ctx<Msg>) {
        if let Some((delay, tag)) = state.step(ctx.now()) {
            state.pending = tag;
            ctx.schedule_self(delay, tags::FAULT_TICK, Some(Msg::Control(k as u64)));
        }
    }
}

impl crate::des::Entity<Msg> for FaultInjector {
    fn name(&self) -> &str {
        &self.name
    }

    fn on_start(&mut self, ctx: &mut Ctx<Msg>) {
        for (k, state) in self.states.iter_mut().enumerate() {
            Self::arm(state, k, ctx);
        }
    }

    fn on_event(&mut self, ctx: &mut Ctx<Msg>, mut ev: Event<Msg>) {
        match ev.tag {
            tags::FAULT_TICK => {
                let Msg::Control(k) = ev.take_data() else {
                    panic!("FAULT_TICK without a resource index payload")
                };
                let k = k as usize;
                let state = &mut self.states[k];
                ctx.send_delayed(state.target, 0.0, state.pending, None);
                Self::arm(state, k, ctx);
            }
            tags::INSIGNIFICANT => {}
            other => panic!("fault injector got unexpected tag {other}"),
        }
    }

    fn as_any(&self) -> &dyn std::any::Any {
        self
    }
    fn as_any_mut(&mut self) -> &mut dyn std::any::Any {
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn state(process: FaultProcess, scaling: f64) -> ProcessState {
        ProcessState {
            target: 0,
            process,
            scaling,
            rng: Rng::new(11 ^ FAULT_SEED_SALT).derive(0),
            up: true,
            pending: tags::INSIGNIFICANT,
            next_interval: 0,
        }
    }

    #[test]
    fn exponential_alternates_fail_recover() {
        let mut st = state(FaultProcess::Exponential { mtbf: 100.0, mttr: 5.0 }, 1.0);
        let mut now = 0.0;
        let mut expect_fail = true;
        for _ in 0..20 {
            let (delay, tag) = st.step(now).unwrap();
            assert!(delay > 0.0);
            let want = if expect_fail { tags::RESOURCE_FAIL } else { tags::RESOURCE_RECOVER };
            assert_eq!(tag, want);
            now += delay;
            expect_fail = !expect_fail;
        }
    }

    #[test]
    fn scaling_scales_uptimes_only() {
        let mut base = state(FaultProcess::Exponential { mtbf: 100.0, mttr: 5.0 }, 1.0);
        let mut half = state(FaultProcess::Exponential { mtbf: 100.0, mttr: 5.0 }, 0.5);
        for i in 0..10 {
            let (db, _) = base.step(0.0).unwrap();
            let (dh, _) = half.step(0.0).unwrap();
            if i % 2 == 0 {
                // Uptime: same uniform draw, scaled mean → exactly half.
                assert!((dh - db * 0.5).abs() <= 1e-12 * db.max(1.0), "{dh} != {db}/2");
            } else {
                // Repair: untouched by scaling.
                assert_eq!(dh, db);
            }
        }
    }

    #[test]
    fn trace_replays_intervals_and_ends() {
        let mut st = state(
            FaultProcess::Trace { intervals: vec![(10.0, 14.0), (30.0, 31.0)] },
            1.0,
        );
        let (d, tag) = st.step(0.0).unwrap();
        assert_eq!((d, tag), (10.0, tags::RESOURCE_FAIL));
        let (d, tag) = st.step(10.0).unwrap();
        assert_eq!((d, tag), (4.0, tags::RESOURCE_RECOVER));
        let (d, tag) = st.step(14.0).unwrap();
        assert_eq!((d, tag), (16.0, tags::RESOURCE_FAIL));
        let (d, tag) = st.step(30.0).unwrap();
        assert_eq!((d, tag), (1.0, tags::RESOURCE_RECOVER));
        assert!(st.step(31.0).is_none(), "trace exhausted → process stops");
    }

    #[test]
    fn trace_scaling_shifts_onset_keeps_duration() {
        let mut st = state(FaultProcess::Trace { intervals: vec![(10.0, 14.0)] }, 0.5);
        let (d, _) = st.step(0.0).unwrap();
        assert_eq!(d, 5.0, "onset scaled");
        let (d, _) = st.step(5.0).unwrap();
        assert_eq!(d, 4.0, "repair duration preserved");
    }

    #[test]
    fn injector_skips_unfaulted_resources() {
        let spec = FaultsSpec::default().override_for(
            "R1",
            FaultProcess::Exponential { mtbf: 10.0, mttr: 1.0 },
        );
        let resources = vec![(3, "R0".to_string()), (4, "R1".to_string())];
        let inj = FaultInjector::new(&spec, &resources, 42);
        assert_eq!(inj.driven(), 1);
        assert_eq!(inj.states[0].target, 4);
    }

    #[test]
    fn per_resource_streams_are_independent_of_list_prefix() {
        // The stream derives from the resource *index*, so two injectors
        // over the same list produce identical samples resource by resource.
        let spec = FaultsSpec::all(FaultProcess::Exponential { mtbf: 10.0, mttr: 1.0 });
        let resources =
            vec![(3, "R0".to_string()), (4, "R1".to_string()), (5, "R2".to_string())];
        let mut a = FaultInjector::new(&spec, &resources, 7);
        let mut b = FaultInjector::new(&spec, &resources, 7);
        for k in 0..3 {
            assert_eq!(a.states[k].step(0.0), b.states[k].step(0.0));
        }
        // Different seeds give different schedules.
        let mut c = FaultInjector::new(&spec, &resources, 8);
        assert_ne!(a.states[0].step(0.0), c.states[0].step(0.0));
    }
}
