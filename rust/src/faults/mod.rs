//! Reliability layer — stochastic failure–repair processes per resource.
//!
//! Production grids lose resources; the paper's §3.6 resource dynamics and
//! ROADMAP item 5 call for availability modeling on top of the kernel-level
//! `RESOURCE_FAIL`/`RESOURCE_RECOVER` hooks. This module supplies the
//! missing driver: a declarative [`FaultsSpec`] (attached to a
//! [`crate::scenario::Scenario`]) selects a [`FaultProcess`] per resource,
//! and the [`FaultInjector`] DES entity walks each process, delivering
//! failure and recovery events at the sampled transition times.
//!
//! ## Determinism contract
//!
//! Fault sampling draws from a dedicated RNG stream per resource, derived
//! from the scenario seed (`Rng::new(seed ^ SALT).derive(resource_index)`),
//! fully independent of the per-user workload streams. Two consequences:
//!
//! * the same seed always produces the same fault schedule (byte-identical
//!   reports at any `--jobs` value), and
//! * common random numbers hold across sweep cells: an
//!   [`mtbf_scaling`](FaultsSpec::mtbf_scaling) of `s` multiplies the same
//!   underlying uniform draws, so uptime samples scale *linearly* in `s`
//!   and the number of failures in a fixed horizon is monotone in `s`.
//!
//! Repair times are deliberately **not** scaled — `mtbf_scaling` sweeps
//! stress how often resources fail, not how long repairs take.

mod injector;

pub use injector::FaultInjector;

use crate::util::rng::Rng;

/// Seed salt separating the fault-injection RNG universe from the per-user
/// workload streams (which derive directly from the scenario seed).
pub const FAULT_SEED_SALT: u64 = 0xD1CE_FA17_5EED_0001;

/// One resource's failure–repair process.
#[derive(Debug, Clone, PartialEq)]
pub enum FaultProcess {
    /// Memoryless failures: uptime ~ Exp(`mtbf`), downtime ~ Exp(`mttr`).
    Exponential {
        /// Mean time between failures (mean uptime), simulation time units.
        mtbf: f64,
        /// Mean time to repair (mean downtime), simulation time units.
        mttr: f64,
    },
    /// Weibull uptimes (aging hardware: `shape > 1` wears out, `shape < 1`
    /// exhibits infant mortality); downtime stays Exp(`mttr`).
    Weibull {
        /// Weibull *scale* (characteristic life): ~63.2% of uptimes fall
        /// below `mtbf`. At `shape = 1` this is exactly Exp(`mtbf`).
        mtbf: f64,
        /// Mean time to repair (exponential), simulation time units.
        mttr: f64,
        /// Weibull shape parameter `k > 0`.
        shape: f64,
    },
    /// Explicit down intervals `(start, end)` in ascending, non-overlapping
    /// simulation time (replayed availability traces). The resource is up
    /// outside the intervals and stays up after the last one.
    Trace {
        /// Down intervals as `(start, end)` pairs, `start < end`, sorted.
        intervals: Vec<(f64, f64)>,
    },
}

impl FaultProcess {
    /// Validate parameter sanity; returns a human-readable complaint.
    ///
    /// The strict JSON loader rejects malformed processes with its own
    /// contextual errors; this is the programmatic-API safety net.
    pub fn validate(&self) -> Result<(), String> {
        let pos = |v: f64, what: &str| -> Result<(), String> {
            if v.is_finite() && v > 0.0 {
                Ok(())
            } else {
                Err(format!("fault process {what} must be finite and positive, got {v}"))
            }
        };
        match self {
            FaultProcess::Exponential { mtbf, mttr } => {
                pos(*mtbf, "mtbf")?;
                pos(*mttr, "mttr")
            }
            FaultProcess::Weibull { mtbf, mttr, shape } => {
                pos(*mtbf, "mtbf")?;
                pos(*mttr, "mttr")?;
                pos(*shape, "shape")
            }
            FaultProcess::Trace { intervals } => {
                let mut prev_end = 0.0_f64;
                for &(start, end) in intervals {
                    if !(start.is_finite() && end.is_finite() && start >= 0.0) {
                        return Err(format!(
                            "trace interval ({start}, {end}) must be finite and non-negative"
                        ));
                    }
                    if end <= start {
                        return Err(format!(
                            "trace interval ({start}, {end}) must have end > start"
                        ));
                    }
                    if start < prev_end {
                        return Err(format!(
                            "trace interval ({start}, {end}) overlaps or precedes the previous one"
                        ));
                    }
                    prev_end = end;
                }
                Ok(())
            }
        }
    }
}

/// Scenario-level fault configuration: which process drives each resource.
///
/// Overrides are a name-keyed `Vec` (not a map) so the spec stays
/// `PartialEq` with a deterministic `Debug` — sweep checkpoint digests
/// stream the `Debug` form.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultsSpec {
    /// Process applied to every resource without an explicit override;
    /// `None` means un-overridden resources never fail.
    pub default: Option<FaultProcess>,
    /// Per-resource overrides, keyed by resource *name*.
    pub overrides: Vec<(String, FaultProcess)>,
    /// Multiplier on uptime samples (and trace failure onsets) — the sweep
    /// axis knob. `1.0` leaves the configured processes untouched; `< 1`
    /// makes resources fail more often. Repair durations are never scaled.
    pub mtbf_scaling: f64,
}

impl Default for FaultsSpec {
    fn default() -> FaultsSpec {
        FaultsSpec { default: None, overrides: Vec::new(), mtbf_scaling: 1.0 }
    }
}

impl FaultsSpec {
    /// A spec with one default process for every resource.
    pub fn all(process: FaultProcess) -> FaultsSpec {
        FaultsSpec { default: Some(process), ..FaultsSpec::default() }
    }

    /// Builder-style per-resource override.
    pub fn override_for(mut self, name: impl Into<String>, process: FaultProcess) -> FaultsSpec {
        self.overrides.push((name.into(), process));
        self
    }

    /// Builder-style MTBF scaling.
    pub fn mtbf_scaling(mut self, s: f64) -> FaultsSpec {
        assert!(s.is_finite() && s > 0.0, "mtbf scaling must be finite and positive");
        self.mtbf_scaling = s;
        self
    }

    /// The process driving resource `name`, if any (override beats default).
    pub fn process_for(&self, name: &str) -> Option<&FaultProcess> {
        self.overrides
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, p)| p)
            .or(self.default.as_ref())
    }

    /// Validate every configured process ([`FaultProcess::validate`]) and
    /// the scaling factor.
    pub fn validate(&self) -> Result<(), String> {
        if !(self.mtbf_scaling.is_finite() && self.mtbf_scaling > 0.0) {
            return Err(format!(
                "mtbf_scaling must be finite and positive, got {}",
                self.mtbf_scaling
            ));
        }
        if let Some(p) = &self.default {
            p.validate()?;
        }
        for (name, p) in &self.overrides {
            p.validate().map_err(|e| format!("resource {name}: {e}"))?;
        }
        Ok(())
    }
}

/// Weibull(`scale`, `shape`) sample by inverse transform.
///
/// Uses `-ln(u)` with `u ∈ (0, 1)` — the same draw pattern as
/// [`Rng::exponential`], so `shape = 1` reproduces Exp(`scale`) *exactly*
/// (bit-identical for the same RNG state).
pub fn weibull(rng: &mut Rng, scale: f64, shape: f64) -> f64 {
    debug_assert!(scale > 0.0 && shape > 0.0);
    let u = loop {
        let u = rng.next_f64();
        if u > 0.0 {
            break u;
        }
    };
    scale * (-u.ln()).powf(1.0 / shape)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn weibull_shape_one_is_exponential() {
        let mut a = Rng::new(42).derive(7);
        let mut b = Rng::new(42).derive(7);
        for _ in 0..100 {
            assert_eq!(weibull(&mut a, 50.0, 1.0), b.exponential(50.0));
        }
    }

    #[test]
    fn weibull_scale_is_linear_in_scale() {
        // Same RNG state → samples scale exactly with the scale parameter
        // (the CRN property the mtbf_scaling axis relies on).
        let mut a = Rng::new(9).derive(0);
        let mut b = Rng::new(9).derive(0);
        for _ in 0..50 {
            let x = weibull(&mut a, 10.0, 2.0);
            let y = weibull(&mut b, 30.0, 2.0);
            assert!((y - 3.0 * x).abs() <= 1e-12 * y.abs().max(1.0), "{y} != 3*{x}");
        }
    }

    #[test]
    fn weibull_mean_sanity() {
        // shape=2, scale=100: mean = 100·Γ(1.5) ≈ 88.6. Loose bounds only —
        // this is a smoke test, not a statistics suite.
        let mut rng = Rng::new(1).derive(0);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| weibull(&mut rng, 100.0, 2.0)).sum::<f64>() / n as f64;
        assert!((80.0..97.0).contains(&mean), "mean {mean}");
    }

    #[test]
    fn process_for_override_beats_default() {
        let spec = FaultsSpec::all(FaultProcess::Exponential { mtbf: 100.0, mttr: 10.0 })
            .override_for("R1", FaultProcess::Trace { intervals: vec![(5.0, 9.0)] });
        assert!(matches!(spec.process_for("R0"), Some(FaultProcess::Exponential { .. })));
        assert!(matches!(spec.process_for("R1"), Some(FaultProcess::Trace { .. })));
        let none = FaultsSpec::default().override_for(
            "R1",
            FaultProcess::Exponential { mtbf: 1.0, mttr: 1.0 },
        );
        assert!(none.process_for("R0").is_none(), "no default → un-overridden never fail");
    }

    #[test]
    fn validate_rejects_bad_parameters() {
        assert!(FaultProcess::Exponential { mtbf: 0.0, mttr: 1.0 }.validate().is_err());
        assert!(FaultProcess::Exponential { mtbf: 1.0, mttr: f64::NAN }.validate().is_err());
        assert!(FaultProcess::Weibull { mtbf: 1.0, mttr: 1.0, shape: -2.0 }
            .validate()
            .is_err());
        assert!(FaultProcess::Trace { intervals: vec![(3.0, 2.0)] }.validate().is_err());
        assert!(FaultProcess::Trace { intervals: vec![(0.0, 2.0), (1.0, 4.0)] }
            .validate()
            .is_err());
        assert!(FaultProcess::Trace { intervals: vec![(0.0, 2.0), (2.0, 4.0)] }
            .validate()
            .is_ok());
        let mut spec = FaultsSpec::all(FaultProcess::Exponential { mtbf: 1.0, mttr: 1.0 });
        assert!(spec.validate().is_ok());
        spec.mtbf_scaling = -1.0;
        assert!(spec.validate().is_err());
    }
}
