//! Flow-level network subsystem: shared-bandwidth contention.
//!
//! The paper (and [`crate::gridsim::network::BaudLink`]) models every
//! transfer with a closed-form delay `latency + bytes·8 / baud`, so N
//! concurrent transfers through one broker each see *full* bandwidth.
//! This module adds the contention-aware alternative: a [`FlowLink`]
//! assigns every entity an access link with a finite capacity (bits per
//! simulation time unit), and every sized [`crate::des::Ctx::send`]
//! becomes a *flow* that fair-shares both endpoints' links with all
//! concurrent flows:
//!
//! ```text
//! rate(f) = min( cap(src)/n(src), cap(dst)/n(dst) )
//! ```
//!
//! where `n(e)` counts the flows currently using entity `e`'s link.
//!
//! ## Event rescheduling
//!
//! Flow state lives in the kernel-owned [`FlowTable`]. Whenever a flow
//! starts or finishes, every flow sharing a touched endpoint settles the
//! bits it transferred at its old rate, takes its new fair-share rate,
//! and pushes a *fresh* finish marker (`EventKind::FlowWake`) into the
//! future-event queue; the previous marker stays queued but is dropped
//! on pop because its sequence number no longer matches the flow's live
//! marker — the same stale-interrupt idiom the paper's entities use for
//! internal events (Figs 7/10), lifted into the kernel. When a live
//! marker fires, the flow *is* complete by definition (no floating-point
//! remaining-bits comparison), and its payload is delivered as an
//! ordinary external event after the model's fixed latency.
//!
//! ## Determinism
//!
//! Everything here is a pure function of the event sequence: flows are
//! identified by a per-simulation counter, recomputation iterates the
//! table in flow-id order (a `BTreeMap`), and simultaneous finishes are
//! ordered by marker sequence number. Flow-model runs are therefore
//! byte-identical at any sweep `--jobs` value, exactly like scalar runs.
//! Scalar models never touch this machinery, so `"baud"` and
//! `"instantaneous"` scenarios keep their pre-flow event streams.

mod flow_link;
mod flow_table;

pub use flow_link::FlowLink;
pub use flow_table::FlowTable;
