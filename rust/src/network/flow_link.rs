//! The shared-bandwidth link model (configuration side).

use crate::des::entity::LinkModel;
use crate::des::EntityId;
use std::collections::HashMap;

/// Fair-share access-link model: every entity owns a link of finite
/// capacity (bits per simulation time unit), and concurrent transfers
/// through a link split it evenly. Installing a `FlowLink` switches the
/// kernel's sized sends from closed-form delays to rescheduled flows —
/// see [`crate::network`] for the mechanics and determinism contract.
///
/// Capacities follow the [`crate::gridsim::network::BaudLink`] convention
/// (a 1200-byte message over a 9600 bit/s link takes one time unit solo),
/// so a `"flow"` scenario with no contention matches its `"baud"` twin.
pub struct FlowLink {
    /// Capacity for entities without an explicit override.
    default_capacity: f64,
    /// Per-entity access-link capacity overrides.
    capacities: HashMap<EntityId, f64>,
    /// Fixed per-message latency, added after a transfer completes (and to
    /// payload-free control messages).
    latency: f64,
}

impl FlowLink {
    /// A flow model where every access link has `default_capacity` bits
    /// per time unit and every delivery adds `latency` on top of the
    /// transfer. Panics on non-finite, zero or negative capacity and on
    /// negative or non-finite latency — the scenario loader rejects such
    /// values with a proper error before this is reached.
    pub fn new(default_capacity: f64, latency: f64) -> FlowLink {
        assert!(
            default_capacity.is_finite() && default_capacity > 0.0,
            "link capacity must be finite and positive, got {default_capacity}"
        );
        assert!(
            latency.is_finite() && latency >= 0.0,
            "latency must be finite and non-negative, got {latency}"
        );
        FlowLink { default_capacity, capacities: HashMap::new(), latency }
    }

    /// Override one entity's access-link capacity (builder style). Panics
    /// on non-finite, zero or negative values, like [`new`](Self::new).
    pub fn with_capacity(mut self, entity: EntityId, capacity: f64) -> FlowLink {
        self.set_capacity(entity, capacity);
        self
    }

    /// Override one entity's access-link capacity in place.
    pub fn set_capacity(&mut self, entity: EntityId, capacity: f64) {
        assert!(
            capacity.is_finite() && capacity > 0.0,
            "link capacity must be finite and positive, got {capacity}"
        );
        self.capacities.insert(entity, capacity);
    }
}

impl LinkModel for FlowLink {
    /// Zero-contention fallback used for payload-free control messages and
    /// self-sends: latency plus the solo transfer time over the slower of
    /// the two endpoints' links (self-sends are free, as in `BaudLink`).
    fn delay(&self, src: EntityId, dst: EntityId, bytes: u64) -> f64 {
        if src == dst {
            return 0.0;
        }
        let rate = self.capacity_of(src).min(self.capacity_of(dst));
        self.latency + bytes as f64 * 8.0 / rate
    }

    fn is_flow(&self) -> bool {
        true
    }

    fn flow_latency(&self) -> f64 {
        self.latency
    }

    fn capacity_of(&self, e: EntityId) -> f64 {
        self.capacities.get(&e).copied().unwrap_or(self.default_capacity)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn solo_delay_matches_baud_convention() {
        // 1200 bytes at 9600 bit/s → 1.0 time units, plus latency.
        let link = FlowLink::new(9600.0, 0.25);
        assert_eq!(link.delay(0, 1, 1200), 1.25);
        assert_eq!(link.delay(2, 2, 1200), 0.0, "self-sends are free");
    }

    #[test]
    fn per_entity_overrides_bottleneck() {
        let link = FlowLink::new(9600.0, 0.0).with_capacity(1, 4800.0);
        assert_eq!(link.capacity_of(0), 9600.0);
        assert_eq!(link.capacity_of(1), 4800.0);
        // The slower endpoint bounds the solo rate.
        assert_eq!(link.delay(0, 1, 1200), 2.0);
    }

    #[test]
    #[should_panic(expected = "capacity must be finite and positive")]
    fn rejects_zero_capacity() {
        let _ = FlowLink::new(0.0, 0.0);
    }

    #[test]
    #[should_panic(expected = "latency must be finite and non-negative")]
    fn rejects_negative_latency() {
        let _ = FlowLink::new(9600.0, -1.0);
    }
}
