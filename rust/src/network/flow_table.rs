//! Kernel-owned state for in-flight shared-bandwidth flows.

use crate::des::{EntityId, Event, EventKind, EventQueue};
use crate::des::entity::LinkModel;
use std::collections::{BTreeMap, HashMap};

/// One in-flight transfer: the payload it will deliver plus its transfer
/// progress under the fair-share rate last assigned to it.
struct Flow<M> {
    src: EntityId,
    dst: EntityId,
    /// Bits still to transfer as of `last_update`.
    remaining_bits: f64,
    /// Simulation time at which `remaining_bits` was last settled.
    last_update: f64,
    /// Fair-share rate (bits per time unit) in effect since `last_update`.
    rate: f64,
    /// Sequence number of this flow's *live* finish marker; markers popped
    /// with any other sequence number are stale and dropped.
    marker_seq: u64,
    /// Protocol tag delivered when the flow completes.
    tag: i64,
    /// Payload delivered when the flow completes.
    data: Option<M>,
}

/// A completed flow's delivery parameters, handed back to the kernel so it
/// can emit the payload as an ordinary external event.
pub(crate) struct CompletedFlow<M> {
    /// Original sender.
    pub(crate) src: EntityId,
    /// Destination entity.
    pub(crate) dst: EntityId,
    /// Protocol tag.
    pub(crate) tag: i64,
    /// Payload (if any).
    pub(crate) data: Option<M>,
}

/// The set of in-flight flows of one simulation, owned by the kernel and
/// consulted on every sized send and every `FlowWake` marker.
///
/// Iteration order (and therefore recompute order, marker insertion order
/// and tie-breaking) is flow-id order — a pure function of the event
/// sequence, which is what keeps flow-model runs byte-identical at any
/// sweep worker count. See [`crate::network`] for the model.
pub struct FlowTable<M> {
    /// In-flight flows, keyed by id in a `BTreeMap` for deterministic
    /// iteration.
    flows: BTreeMap<u64, Flow<M>>,
    /// Number of flows currently using each entity's access link.
    active: HashMap<EntityId, usize>,
    /// Next flow id (per-simulation counter).
    next_id: u64,
}

impl<M> Default for FlowTable<M> {
    fn default() -> Self {
        Self::new()
    }
}

impl<M> FlowTable<M> {
    /// An empty table (no flows in flight).
    pub fn new() -> FlowTable<M> {
        FlowTable { flows: BTreeMap::new(), active: HashMap::new(), next_id: 0 }
    }

    /// Number of flows currently in flight.
    pub fn len(&self) -> usize {
        self.flows.len()
    }

    /// True when no flow is in flight.
    pub fn is_empty(&self) -> bool {
        self.flows.is_empty()
    }

    /// Register a new flow of `bytes` from `src` to `dst` starting at
    /// `now`, then recompute rates for every flow sharing either endpoint
    /// (the new flow included). Returns the new flow's finish-marker
    /// sequence number.
    #[allow(clippy::too_many_arguments)] // kernel-internal; mirrors Ctx::send
    pub(crate) fn begin(
        &mut self,
        now: f64,
        src: EntityId,
        dst: EntityId,
        tag: i64,
        data: Option<M>,
        bytes: u64,
        link: &dyn LinkModel,
        queue: &mut EventQueue<M>,
    ) -> u64 {
        debug_assert!(bytes > 0 && src != dst, "zero-byte and self sends stay scalar");
        let id = self.next_id;
        self.next_id += 1;
        self.flows.insert(
            id,
            Flow {
                src,
                dst,
                remaining_bits: bytes as f64 * 8.0,
                last_update: now,
                rate: 0.0,
                marker_seq: 0, // assigned by the recompute below
                tag,
                data,
            },
        );
        *self.active.entry(src).or_insert(0) += 1;
        *self.active.entry(dst).or_insert(0) += 1;
        self.recompute(now, src, dst, link, queue);
        self.flows[&id].marker_seq
    }

    /// True when `marker_seq` is the live finish marker of flow `id`; a
    /// mismatch means a later recompute superseded the popped marker.
    pub(crate) fn is_live(&self, id: u64, marker_seq: u64) -> bool {
        self.flows.get(&id).is_some_and(|f| f.marker_seq == marker_seq)
    }

    /// Remove a completed flow (its live marker fired) and release both
    /// endpoints' link shares. The caller delivers the returned payload and
    /// then recomputes the touched endpoints.
    pub(crate) fn complete(&mut self, id: u64) -> CompletedFlow<M> {
        let flow = self.flows.remove(&id).expect("live marker for unknown flow");
        for e in [flow.src, flow.dst] {
            let n = self.active.get_mut(&e).expect("completed flow not counted");
            *n -= 1;
            if *n == 0 {
                self.active.remove(&e);
            }
        }
        CompletedFlow { src: flow.src, dst: flow.dst, tag: flow.tag, data: flow.data }
    }

    /// Reschedule every flow using endpoint `a` or `b`: settle the bits
    /// transferred at the old rate, assign the new fair-share rate, and
    /// push a fresh finish marker (superseding the old one, which becomes
    /// stale). Flows on untouched links keep their markers — rates depend
    /// only on per-link flow counts, so no recomputation can cascade.
    pub(crate) fn recompute(
        &mut self,
        now: f64,
        a: EntityId,
        b: EntityId,
        link: &dyn LinkModel,
        queue: &mut EventQueue<M>,
    ) {
        for (id, flow) in self.flows.iter_mut() {
            if flow.src != a && flow.src != b && flow.dst != a && flow.dst != b {
                continue;
            }
            if flow.rate > 0.0 {
                let done = flow.rate * (now - flow.last_update);
                flow.remaining_bits = (flow.remaining_bits - done).max(0.0);
            }
            flow.last_update = now;
            let share = |e: EntityId| link.capacity_of(e) / self.active[&e] as f64;
            flow.rate = share(flow.src).min(share(flow.dst));
            debug_assert!(
                flow.rate > 0.0 && !flow.rate.is_nan(),
                "flow rate must be positive, got {}",
                flow.rate
            );
            let dt = if flow.rate.is_finite() { flow.remaining_bits / flow.rate } else { 0.0 };
            flow.marker_seq = queue.push(Event {
                time: now + dt,
                seq: 0, // assigned by the queue
                src: flow.src,
                dst: flow.dst,
                tag: *id as i64,
                kind: EventKind::FlowWake,
                data: None,
            });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::network::FlowLink;

    #[test]
    fn two_flows_on_one_link_halve_the_rate() {
        let link = FlowLink::new(1000.0, 0.0);
        let mut table: FlowTable<()> = FlowTable::new();
        let mut queue: EventQueue<()> = EventQueue::new();
        // Flow 0 alone: 1000 bits at 1000 b/s → marker at t=1.
        table.begin(0.0, 0, 1, 7, None, 125, &link, &mut queue);
        assert_eq!(table.len(), 1);
        // Flow 1 joins at t=0.5 sharing src 0: both drop to 500 b/s.
        // Flow 0 has 500 bits left → finishes at 0.5 + 1 = 1.5.
        let seq1 = table.begin(0.5, 0, 2, 8, None, 125, &link, &mut queue);
        // Queue now holds flow 0's stale marker (t=1), then fresh markers
        // for both flows: flow 0 at t=1.5, flow 1 at t=0.5 + 2.
        let stale = queue.pop().unwrap();
        assert_eq!(stale.time, 1.0);
        assert!(!table.is_live(stale.tag as u64, stale.seq), "superseded marker is stale");
        let live0 = queue.pop().unwrap();
        assert_eq!(live0.time, 1.5);
        assert!(table.is_live(live0.tag as u64, live0.seq));
        let done = table.complete(live0.tag as u64);
        assert_eq!((done.src, done.dst, done.tag), (0, 1, 7));
        // Flow 1 recomputes back to full rate: 1750 bits... no — it had
        // 1000 bits at t=0.5, ran at 500 b/s for 1.0s → 500 left at t=1.5,
        // now alone at 1000 b/s → finishes at t=2.
        table.recompute(1.5, done.src, done.dst, &link, &mut queue);
        let live1 = queue.pop().unwrap();
        assert!(table.is_live(live1.tag as u64, live1.seq));
        assert_eq!(live1.time, 2.0);
        let _ = seq1;
    }

    #[test]
    fn counts_release_on_complete() {
        let link = FlowLink::new(100.0, 0.0);
        let mut table: FlowTable<()> = FlowTable::new();
        let mut queue: EventQueue<()> = EventQueue::new();
        table.begin(0.0, 0, 1, 1, None, 10, &link, &mut queue);
        table.begin(0.0, 1, 2, 2, None, 10, &link, &mut queue);
        assert_eq!(table.len(), 2);
        table.complete(0);
        table.complete(1);
        assert!(table.is_empty());
        assert!(table.active.is_empty(), "link shares released");
    }
}
