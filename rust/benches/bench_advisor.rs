//! Advisor engine comparison: the broker's per-tick allocation decision,
//! native Rust vs the AOT JAX/Pallas artifact through PJRT
//! (EXPERIMENTS.md §Perf L1/L2). Skips the XLA half when artifacts are
//! missing.

mod harness;

use gridsim::runtime::{
    Advisor, AdvisorInput, ForecastInput, NativeAdvisor, ResourceSnapshot, XlaAdvisor,
    XlaForecaster,
};
use harness::{bench, metric};
use std::path::Path;
use std::time::Instant;

fn wwg_input() -> AdvisorInput {
    // The 11-resource WWG testbed, cost-sorted, paper-scale scalars.
    let mut snaps = vec![
        ResourceSnapshot { rate_mi: 760.0, cost_per_mi: 1.0 / 380.0 },
        ResourceSnapshot { rate_mi: 760.0, cost_per_mi: 2.0 / 380.0 },
        ResourceSnapshot { rate_mi: 1508.0, cost_per_mi: 3.0 / 377.0 },
        ResourceSnapshot { rate_mi: 754.0, cost_per_mi: 3.0 / 377.0 },
        ResourceSnapshot { rate_mi: 3016.0, cost_per_mi: 3.0 / 377.0 },
        ResourceSnapshot { rate_mi: 6560.0, cost_per_mi: 4.0 / 410.0 },
        ResourceSnapshot { rate_mi: 1508.0, cost_per_mi: 4.0 / 377.0 },
        ResourceSnapshot { rate_mi: 2460.0, cost_per_mi: 5.0 / 410.0 },
        ResourceSnapshot { rate_mi: 6560.0, cost_per_mi: 5.0 / 410.0 },
        ResourceSnapshot { rate_mi: 1640.0, cost_per_mi: 6.0 / 410.0 },
        ResourceSnapshot { rate_mi: 2060.0, cost_per_mi: 8.0 / 515.0 },
    ];
    snaps.sort_by(|a, b| a.cost_per_mi.total_cmp(&b.cost_per_mi));
    AdvisorInput {
        resources: snaps,
        time_left: 3_100.0,
        budget_left: 22_000.0,
        avg_job_mi: 10_500.0,
        jobs: 200,
    }
}

fn main() {
    println!("== bench_advisor: scheduling-decision engines ==");
    let input = wwg_input();

    let mut native = NativeAdvisor::new();
    bench("native_advisor/11res/200jobs", 100, 10, || native.advise(&input));
    let t0 = Instant::now();
    let n = 100_000;
    for _ in 0..n {
        std::hint::black_box(native.advise(&input));
    }
    metric("native_advisor_decisions_per_sec", n as f64 / t0.elapsed().as_secs_f64(), "dec/s");

    let dir = Path::new("artifacts");
    if !cfg!(feature = "xla") {
        println!("SKIP xla half: built without the `xla` cargo feature");
    } else if dir.join("advisor.hlo.txt").exists() {
        let mut xla = XlaAdvisor::load_dir(dir).expect("load advisor artifact");
        // Sanity: engines agree before we time them.
        assert_eq!(native.advise(&input), xla.advise(&input));
        bench("xla_advisor/11res/200jobs", 10, 10, || xla.advise(&input));
        let t0 = Instant::now();
        let n = 2_000;
        for _ in 0..n {
            std::hint::black_box(xla.advise(&input));
        }
        metric("xla_advisor_decisions_per_sec", n as f64 / t0.elapsed().as_secs_f64(), "dec/s");

        let mut fc = XlaForecaster::load_dir(dir).expect("load forecast artifact");
        let forecast_input = ForecastInput {
            remaining_mi: (0..11)
                .map(|r| (0..64).map(|j| 1_000.0 + (r * 64 + j) as f64).collect())
                .collect(),
            mips_per_pe: vec![400.0; 11],
            num_pe: vec![4; 11],
            availability: vec![1.0; 11],
        };
        bench("xla_forecast/11res/64jobs", 10, 10, || fc.forecast(&forecast_input).unwrap());
    } else {
        println!("(artifacts/ missing — run `make artifacts` for the XLA half)");
    }
}
