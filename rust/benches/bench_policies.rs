//! Ablation: the four DBC optimization strategies (paper §4.2.2 — cost,
//! time, cost-time [23], none) on identical workloads. This is the design
//! choice the broker exists to compare: the cost/time trade-off and where
//! cost-time lands between them.

mod harness;

use gridsim::broker::{ExperimentSpec, Optimization};
use gridsim::config::testbed::wwg_testbed;
use gridsim::scenario::Scenario;
use gridsim::session::GridSession;
use harness::bench;

fn run(opt: Optimization, deadline: f64, budget: f64) -> (usize, f64, f64) {
    let scenario = Scenario::builder()
        .resources(wwg_testbed())
        .user(
            ExperimentSpec::task_farm(100, 10_000.0, 0.10)
                .deadline(deadline)
                .budget(budget)
                .optimization(opt),
        )
        .seed(27)
        .build();
    let report = GridSession::new(&scenario).run_to_completion();
    let u = &report.users[0];
    (u.gridlets_completed, u.finish_time - u.start_time, u.budget_spent)
}

fn main() {
    println!("== bench_policies: DBC optimization-strategy ablation (paper §4.2.2) ==");
    let all = [
        Optimization::Cost,
        Optimization::Time,
        Optimization::CostTime,
        Optimization::NoOpt,
    ];
    for (label, deadline, budget) in [
        ("tight deadline 300, budget 22000", 300.0, 22_000.0),
        ("relaxed deadline 3100, budget 60000", 3_100.0, 60_000.0),
        ("starved budget 4000, deadline 3100", 3_100.0, 4_000.0),
    ] {
        println!("--- {label} ---");
        println!("{:>10} {:>9} {:>10} {:>11}", "policy", "done", "time", "spent(G$)");
        for opt in all {
            let (done, time, spent) = run(opt, deadline, budget);
            println!("{:>10} {:>6}/100 {:>10.1} {:>11.1}", opt.label(), done, time, spent);
        }
    }
    println!();
    println!("expected ablation shapes: time-opt fastest+costliest; cost-opt cheapest;");
    println!("cost-time between them (equal-price pools in parallel); none widest spread.");
    println!();
    for opt in all {
        bench(
            &format!("policy/{}/100jobs/d3100", opt.label()),
            1,
            3,
            || run(opt, 3_100.0, 60_000.0),
        );
    }
}
