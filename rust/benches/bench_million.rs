//! The million-job hot-path campaign bench (ROADMAP north star: million-job
//! replay "as fast as the hardware allows").
//!
//! Two sections, both feeding the committed `BENCH_<date>.json` trajectory:
//!
//! 1. **Replay** — a synthetic SWF-style trace (generated deterministically
//!    here, never committed) of `BENCH_MILLION_JOBS` jobs (default 1,000,000)
//!    split across `BENCH_MILLION_USERS` users (default 50, clamped to the
//!    paper-scale 10–100 band), run through the full stack: users stream
//!    arrivals, brokers schedule, resources execute, results return. Reports
//!    `million_replay_events_per_sec`, wall seconds, and peak RSS.
//! 2. **Ping storm** — a pure-kernel microbench: a ring of entities
//!    bouncing payload-free events through the future-event queue with no
//!    broker logic at all, isolating queue push/pop + dispatch cost.
//!    Reports `kernel_pingstorm_events_per_sec`.
//!
//! CI's bench-smoke job runs this with `BENCH_MILLION_JOBS=50000` and gates
//! on >2x events/sec regressions via `tools/bench_gate.py`.

mod harness;

use gridsim::broker::{ExperimentSpec, Optimization};
use gridsim::config::testbed::wwg_testbed;
use gridsim::des::{Ctx, Entity, Event, SimConfig, Simulation};
use gridsim::scenario::Scenario;
use gridsim::session::GridSession;
use gridsim::workload::{TraceJob, TraceSelector, WorkloadSpec};
use harness::Recorder;
use std::sync::Arc;
use std::time::Instant;

fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

/// Deterministic synthetic SWF-style log: `jobs` entries spread over `users`
/// users with staggered submit times and mildly varied lengths/file sizes.
/// Pure arithmetic on the index — same log every run, nothing committed.
fn synthetic_trace(jobs: usize, users: usize) -> Arc<[TraceJob]> {
    let log: Vec<TraceJob> = (0..jobs)
        .map(|i| {
            let mut j = TraceJob::new(
                (i % 9973) as f64 * 0.25,
                4_000.0 + (i % 17) as f64 * 250.0,
                1_000,
                500,
            );
            j.user = Some((i % users) as i64);
            j
        })
        .collect();
    log.into()
}

fn replay_section(rec: &mut Recorder) {
    let jobs = env_usize("BENCH_MILLION_JOBS", 1_000_000);
    let users = env_usize("BENCH_MILLION_USERS", 50).clamp(10, 100);
    println!("-- replay: {jobs} jobs across {users} users --");
    let shared = synthetic_trace(jobs, users);

    let mut builder = Scenario::builder().resources(wwg_testbed()).seed(41);
    for u in 0..users as i64 {
        builder = builder.user(
            ExperimentSpec::new(WorkloadSpec::trace_selected_shared(
                shared.clone(),
                TraceSelector::user(u),
            ))
            .deadline(1e9)
            .budget(1e15)
            .optimization(Optimization::Cost),
        );
    }
    let scenario = builder.build();

    let t0 = Instant::now();
    let report = GridSession::new(&scenario).run_to_completion();
    let wall = t0.elapsed().as_secs_f64();

    rec.metric("million_replay_jobs", jobs as f64, "jobs");
    rec.metric("million_replay_wall", wall, "s");
    rec.metric(
        "million_replay_events_per_sec",
        report.events as f64 / wall.max(1e-9),
        "events/s",
    );
    rec.maybe_metric("million_replay_peak_rss", harness::peak_rss_bytes(), "B");
}

/// One node of the ping-storm ring: keeps `fanout` events in flight toward
/// the next entity forever; the kernel's `max_events` limit ends the run.
struct Storm {
    name: String,
    next: usize,
    fanout: u64,
}

impl Entity<u32> for Storm {
    fn name(&self) -> &str {
        &self.name
    }
    fn on_start(&mut self, ctx: &mut Ctx<u32>) {
        for k in 0..self.fanout {
            ctx.send_delayed(self.next, 0.5 + k as f64 * 0.25, 0, None);
        }
    }
    fn on_event(&mut self, ctx: &mut Ctx<u32>, _ev: Event<u32>) {
        ctx.send_delayed(self.next, 1.0, 0, None);
    }
    fn as_any(&self) -> &dyn std::any::Any {
        self
    }
    fn as_any_mut(&mut self) -> &mut dyn std::any::Any {
        self
    }
}

fn pingstorm_section(rec: &mut Recorder) {
    let events = env_usize("BENCH_PINGSTORM_EVENTS", 1_000_000) as u64;
    let entities = 64;
    let fanout = 8;
    println!("-- ping storm: {events} events, {entities}-entity ring, fanout {fanout} --");
    let mut sim: Simulation<u32> =
        Simulation::with_config(SimConfig { max_time: f64::INFINITY, max_events: events });
    for i in 0..entities {
        sim.add(Box::new(Storm {
            name: format!("S{i}"),
            next: (i + 1) % entities,
            fanout,
        }));
    }
    let t0 = Instant::now();
    sim.run();
    let wall = t0.elapsed().as_secs_f64();
    assert_eq!(sim.events_processed(), events, "storm must hit the event cap");

    rec.metric("kernel_pingstorm_events", events as f64, "events");
    rec.metric("kernel_pingstorm_wall", wall, "s");
    rec.metric(
        "kernel_pingstorm_events_per_sec",
        events as f64 / wall.max(1e-9),
        "events/s",
    );
}

fn main() {
    println!("== bench_million: kernel hot-path campaign ==");
    let mut rec = Recorder::new("bench_million");
    pingstorm_section(&mut rec);
    replay_section(&mut rec);
    match rec.write_snapshot(&harness::snapshot_dir()) {
        Ok(path) => println!("snapshot written: {path}"),
        Err(e) => eprintln!("snapshot not written: {e}"),
    }
}
