//! Engine microbenchmarks: raw event throughput of the DES kernel — the
//! foundation every experiment rests on (EXPERIMENTS.md §Perf L3).

mod harness;

use gridsim::des::{Ctx, Entity, EntityId, Event, Simulation};
use harness::{bench, metric};
use std::time::Instant;

/// Ring of entities forwarding a token; stresses queue + dispatch.
struct Forwarder {
    name: String,
    next: EntityId,
    hops_left: u64,
    start: bool,
}

impl Entity<u64> for Forwarder {
    fn name(&self) -> &str {
        &self.name
    }
    fn on_start(&mut self, ctx: &mut Ctx<u64>) {
        if self.start {
            ctx.send_delayed(self.next, 1.0, 0, Some(self.hops_left));
        }
    }
    fn on_event(&mut self, ctx: &mut Ctx<u64>, mut ev: Event<u64>) {
        let n = ev.take_data();
        if n > 0 {
            ctx.send_delayed(self.next, 1.0, 0, Some(n - 1));
        } else {
            ctx.stop();
        }
    }
    fn as_any(&self) -> &dyn std::any::Any {
        self
    }
    fn as_any_mut(&mut self) -> &mut dyn std::any::Any {
        self
    }
}

fn ring(entities: usize, hops: u64) -> u64 {
    let mut sim: Simulation<u64> = Simulation::new();
    for i in 0..entities {
        sim.add(Box::new(Forwarder {
            name: format!("f{i}"),
            next: (i + 1) % entities,
            hops_left: hops,
            start: i == 0,
        }));
    }
    sim.run();
    sim.events_processed()
}

/// Self-scheduling storm: every entity keeps `k` outstanding self-events;
/// stresses the binary heap at depth.
struct Storm {
    name: String,
    remaining: u64,
}

impl Entity<u64> for Storm {
    fn name(&self) -> &str {
        &self.name
    }
    fn on_start(&mut self, ctx: &mut Ctx<u64>) {
        for i in 0..8 {
            ctx.schedule_self(1.0 + i as f64 * 0.1, 0, None);
        }
    }
    fn on_event(&mut self, ctx: &mut Ctx<u64>, _ev: Event<u64>) {
        if self.remaining > 0 {
            self.remaining -= 1;
            ctx.schedule_self(1.0, 0, None);
        }
    }
    fn as_any(&self) -> &dyn std::any::Any {
        self
    }
    fn as_any_mut(&mut self) -> &mut dyn std::any::Any {
        self
    }
}

fn storm(entities: usize, events_each: u64) -> u64 {
    let mut sim: Simulation<u64> = Simulation::new();
    for i in 0..entities {
        sim.add(Box::new(Storm { name: format!("s{i}"), remaining: events_each }));
    }
    sim.run();
    sim.events_processed()
}

fn main() {
    println!("== bench_engine: DES kernel throughput ==");
    // `run()` is implemented on the stepped init/step/finalize API, so this
    // headline number *is* the stepped-execution throughput.
    bench("ring/2ents/100k-hops", 1, 5, || ring(2, 100_000));
    bench("ring/64ents/100k-hops", 1, 5, || ring(64, 100_000));
    bench("storm/100ents/1k-events-each", 1, 5, || storm(100, 1_000));

    // Headline events/s metric.
    let t0 = Instant::now();
    let events = ring(16, 1_000_000);
    let dt = t0.elapsed().as_secs_f64();
    metric("engine_events_per_sec", events as f64 / dt, "events/s");
}
