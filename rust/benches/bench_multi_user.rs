//! Regenerates the paper's multi-user competition series (Figures 33–38)
//! at reduced scale, times representative cells, and measures the sweep
//! engine's parallel speedup over the serial baseline — the §5.4 bench.

mod harness;

use gridsim::figures::{figs33_38, FigureConfig};
use harness::{bench, metric, Recorder};
use std::time::Instant;

fn main() {
    println!("== bench_multi_user: paper §5.4 (Figures 33–38) ==");
    let mut rec = Recorder::new("bench_multi_user");

    let cfg = FigureConfig {
        user_counts: vec![1, 5, 10, 20],
        budgets: vec![6_000.0, 12_000.0, 22_000.0],
        gridlets: 60,
        ..FigureConfig::quick()
    };
    for (label, deadline) in
        [("Figs 33-35 (deadline 3100)", 3_100.0), ("Figs 36-38 (deadline 10000)", 10_000.0)]
    {
        let t0 = Instant::now();
        let csv = figs33_38(deadline, &cfg);
        println!("--- {label} ---");
        print!("{}", csv.to_string());
        println!("--- in {:.2}s ---", t0.elapsed().as_secs_f64());
    }

    // Timed: one heavy competition cell.
    bench("competition/20users/60jobs/d3100", 1, 3, || {
        let c = FigureConfig {
            user_counts: vec![20],
            budgets: vec![12_000.0],
            gridlets: 60,
            ..FigureConfig::quick()
        };
        figs33_38(3_100.0, &c).len()
    });

    // Scaling metric: events/s with 40 brokers live, via GridSession.
    use gridsim::broker::{ExperimentSpec, Optimization};
    use gridsim::config::testbed::wwg_testbed;
    use gridsim::scenario::Scenario;
    use gridsim::session::GridSession;
    let scenario = Scenario::builder()
        .resources(wwg_testbed())
        .users(
            40,
            ExperimentSpec::task_farm(40, 10_000.0, 0.10)
                .deadline(3_100.0)
                .budget(12_000.0)
                .optimization(Optimization::Cost),
        )
        .seed(17)
        .build();
    let t0 = Instant::now();
    let report = GridSession::new(&scenario).run_to_completion();
    rec.metric(
        "multi_user_events_per_sec(40 users)",
        report.events as f64 / t0.elapsed().as_secs_f64(),
        "events/s",
    );

    // Heterogeneous competition cell: the 40 users split across all four
    // DBC policies (per-user overrides), same market.
    let policies =
        [Optimization::Cost, Optimization::Time, Optimization::CostTime, Optimization::NoOpt];
    let mut builder = Scenario::builder().resources(wwg_testbed()).seed(17);
    for i in 0..40 {
        builder = builder.user(
            ExperimentSpec::task_farm(40, 10_000.0, 0.10)
                .deadline(3_100.0)
                .budget(12_000.0)
                .optimization(policies[i % policies.len()]),
        );
    }
    let scenario = builder.build();
    let t0 = Instant::now();
    let report = GridSession::new(&scenario).run_to_completion();
    rec.metric(
        "heterogeneous_events_per_sec(40 users, 4 policies)",
        report.events as f64 / t0.elapsed().as_secs_f64(),
        "events/s",
    );

    // Flow vs baud network: the same 10-user online-arrival market under
    // the zero-contention BaudLink and the fair-share FlowLink. The flow
    // model pays for its rescheduling markers (O(flows on the touched
    // links) per start/finish); this pins the overhead next to the
    // baseline in every snapshot.
    {
        use gridsim::scenario::NetworkSpec;
        use gridsim::workload::{ArrivalProcess, WorkloadSpec};
        let build = |network: NetworkSpec| {
            let workload = WorkloadSpec::online(
                WorkloadSpec::task_farm(40, 10_000.0, 0.10),
                ArrivalProcess::Poisson { mean_interarrival: 10.0 },
            );
            let mut builder = Scenario::builder().resources(wwg_testbed()).seed(29);
            for _ in 0..10 {
                builder = builder.user(
                    ExperimentSpec::new(workload.clone())
                        .deadline(1e6)
                        .budget(1e9)
                        .optimization(Optimization::Cost),
                );
            }
            builder.network(network).build()
        };
        for (label, network) in [
            ("baud", NetworkSpec::Baud { default_rate: 9_600.0, latency: 0.05 }),
            (
                "flow",
                NetworkSpec::Flow {
                    default_capacity: 9_600.0,
                    latency: 0.05,
                    capacities: vec![],
                },
            ),
        ] {
            let scenario = build(network);
            let t0 = Instant::now();
            let report = GridSession::new(&scenario).run_to_completion();
            let wall = t0.elapsed().as_secs_f64();
            rec.metric(
                &format!("network_{label}_wall(10 users, online arrivals)"),
                wall,
                "s",
            );
            rec.metric(
                &format!("network_{label}_events_per_sec"),
                report.events as f64 / wall.max(1e-9),
                "events/s",
            );
        }
    }

    // Faulted vs clean: the same 10-user market with and without the
    // reliability layer's failure–repair injection (default retry policy).
    // Pins the injector's event overhead — fault ticks, drains,
    // resubmission round-trips — next to the clean baseline in every
    // snapshot.
    {
        use gridsim::faults::{FaultProcess, FaultsSpec};
        let build = |faults: Option<FaultsSpec>| {
            let mut builder = Scenario::builder().resources(wwg_testbed()).seed(31);
            for _ in 0..10 {
                builder = builder.user(
                    ExperimentSpec::task_farm(40, 10_000.0, 0.10)
                        .deadline(1e6)
                        .budget(1e9)
                        .optimization(Optimization::Cost),
                );
            }
            if let Some(f) = faults {
                builder = builder.faults(f);
            }
            builder.build()
        };
        for (label, faults) in [
            ("clean", None),
            (
                "faulted",
                Some(FaultsSpec::all(FaultProcess::Exponential { mtbf: 400.0, mttr: 40.0 })),
            ),
        ] {
            let faulted = faults.is_some();
            let scenario = build(faults);
            let t0 = Instant::now();
            let report = GridSession::new(&scenario).run_to_completion();
            let wall = t0.elapsed().as_secs_f64();
            rec.metric(&format!("reliability_{label}_wall(10 users)"), wall, "s");
            rec.metric(
                &format!("reliability_{label}_events_per_sec"),
                report.events as f64 / wall.max(1e-9),
                "events/s",
            );
            if faulted {
                let lost: usize = report.users.iter().map(|u| u.gridlets_lost).sum();
                rec.metric("reliability_faulted_gridlets_lost", lost as f64, "gridlets");
            }
        }
    }

    // Sweep engine: serial vs parallel over the same grid. The grid is the
    // Figs 33–35 competition block (users × budgets at deadline 3100);
    // near-linear speedup is expected while cells outnumber cores.
    use gridsim::output::sweep::long_csv;
    use gridsim::sweep::{default_jobs, run_sweep, SweepSpec};
    let base = Scenario::builder()
        .resources(wwg_testbed())
        .user(
            ExperimentSpec::task_farm(40, 10_000.0, 0.10)
                .deadline(3_100.0)
                .budget(12_000.0)
                .optimization(Optimization::Cost),
        )
        .seed(17)
        .build();
    let spec = SweepSpec::over(base)
        .user_counts(vec![1, 5, 10, 20])
        .budgets(vec![6_000.0, 12_000.0, 22_000.0])
        .replications(2);
    println!(
        "-- sweep speedup: {} cells, 1 vs {} worker(s) --",
        spec.cell_count(),
        default_jobs()
    );
    let serial = run_sweep(&spec, 1).expect("serial sweep");
    let parallel = run_sweep(&spec, default_jobs()).expect("parallel sweep");
    rec.metric("sweep_serial_wall", serial.wall_secs, "s");
    rec.metric("sweep_parallel_wall", parallel.wall_secs, "s");
    rec.metric(
        "sweep_speedup",
        serial.wall_secs / parallel.wall_secs.max(1e-9),
        &format!("x ({} workers)", parallel.jobs),
    );
    rec.metric(
        "sweep_peak_cells_per_sec",
        parallel.outcomes.len() as f64 / parallel.wall_secs.max(1e-9),
        "cells/s",
    );
    assert_eq!(
        long_csv(&spec, &serial).to_string(),
        long_csv(&spec, &parallel).to_string(),
        "sweep output must be byte-identical across worker counts"
    );
    println!("sweep determinism: serial and parallel CSV byte-identical");

    // Shared-trace memory/throughput: one synthetic 100k-record log split
    // across 20 users, every sweep cell sharing the single Arc allocation.
    // The clone bench is what each cell pays to materialize its scenario —
    // before Arc sharing it deep-copied 20 × 100k TraceJobs per cell.
    use gridsim::workload::{TraceJob, TraceSelector, WorkloadSpec};
    use std::sync::Arc;
    let jobs: Vec<TraceJob> = (0..100_000)
        .map(|i| {
            let mut j = TraceJob::new(
                (i % 977) as f64 * 0.5,
                8_000.0 + (i % 13) as f64 * 250.0,
                1_000,
                500,
            );
            j.user = Some((i % 20) as i64);
            j
        })
        .collect();
    let shared: Arc<[TraceJob]> = jobs.into();
    metric(
        "shared_trace_log_bytes(100k jobs, 1 allocation)",
        (shared.len() * std::mem::size_of::<TraceJob>()) as f64,
        "B",
    );
    let mut builder = Scenario::builder().resources(wwg_testbed()).seed(23);
    for u in 0..20i64 {
        builder = builder.user(
            gridsim::broker::ExperimentSpec::new(WorkloadSpec::trace_selected_shared(
                shared.clone(),
                TraceSelector::user(u).with_max_jobs(40),
            ))
            .deadline(3_100.0)
            .budget(22_000.0)
            .optimization(Optimization::Cost),
        );
    }
    let base = builder.build();
    bench("shared_trace_scenario_clone(20 users x 100k-job log)", 2, 5, || {
        std::hint::black_box(base.clone()).users.len()
    });
    let spec = SweepSpec::over(base)
        .budgets(vec![6_000.0, 12_000.0, 22_000.0])
        .replications(2);
    let t0 = Instant::now();
    let shared_run = run_sweep(&spec, default_jobs()).expect("shared-trace sweep");
    rec.metric("shared_trace_sweep_wall(6 cells, 20 users)", t0.elapsed().as_secs_f64(), "s");
    rec.metric(
        "shared_trace_sweep_events_per_sec",
        shared_run.total_events() as f64 / t0.elapsed().as_secs_f64().max(1e-9),
        "events/s",
    );
    let serial_trace = run_sweep(&spec, 1).expect("serial shared-trace sweep");
    assert_eq!(
        long_csv(&spec, &shared_run).to_string(),
        long_csv(&spec, &serial_trace).to_string(),
        "shared-trace sweep output must be byte-identical across worker counts"
    );
    println!("shared-trace determinism: serial and parallel CSV byte-identical");

    // Workflow layer: a wide fork–join DAG per user (prep → 40 branches →
    // post) across 10 users. Every branch is precedence-released by a
    // completion notice and the join waits for all 40 parents, so this pins
    // the workflow protocol's event overhead — notices, gated arrivals,
    // join bookkeeping — next to the task-farm baselines in every snapshot.
    {
        use gridsim::workload::DagNode;
        let width = 40usize;
        let mut nodes = vec![DagNode::new("prep", 5_000.0)];
        let mut edges = Vec::new();
        for b in 0..width {
            let id = format!("sim{b}");
            nodes.push(DagNode::new(&id, 8_000.0 + 200.0 * b as f64));
            edges.push(("prep".to_string(), id.clone()));
            edges.push((id, "post".to_string()));
        }
        nodes.push(DagNode::new("post", 5_000.0));
        let workload = WorkloadSpec::dag(nodes, edges);
        let mut builder = Scenario::builder().resources(wwg_testbed()).seed(37);
        for _ in 0..10 {
            builder = builder.user(
                ExperimentSpec::new(workload.clone())
                    .deadline(1e6)
                    .budget(1e9)
                    .optimization(Optimization::Cost),
            );
        }
        let scenario = builder.build();
        let t0 = Instant::now();
        let report = GridSession::new(&scenario).run_to_completion();
        let wall = t0.elapsed().as_secs_f64();
        let done: usize = report.users.iter().map(|u| u.gridlets_completed).sum();
        assert_eq!(done, 10 * (width + 2), "every workflow job completes");
        rec.metric(&format!("workflow_forkjoin_wall(10 users, width {width})"), wall, "s");
        rec.metric(
            "workflow_forkjoin_events_per_sec",
            report.events as f64 / wall.max(1e-9),
            "events/s",
        );
    }

    match rec.write_snapshot(&harness::snapshot_dir()) {
        Ok(path) => println!("snapshot written: {path}"),
        Err(e) => eprintln!("snapshot not written: {e}"),
    }
}
