//! Regenerates the paper's multi-user competition series (Figures 33–38)
//! at reduced scale and times representative cells — the §5.4 bench.

mod harness;

use gridsim::figures::{figs33_38, SweepConfig};
use harness::{bench, metric};
use std::time::Instant;

fn main() {
    println!("== bench_multi_user: paper §5.4 (Figures 33–38) ==");

    let cfg = SweepConfig {
        user_counts: vec![1, 5, 10, 20],
        budgets: vec![6_000.0, 12_000.0, 22_000.0],
        gridlets: 60,
        ..SweepConfig::quick()
    };
    for (label, deadline) in [("Figs 33-35 (deadline 3100)", 3_100.0), ("Figs 36-38 (deadline 10000)", 10_000.0)] {
        let t0 = Instant::now();
        let csv = figs33_38(deadline, &cfg);
        println!("--- {label} ---");
        print!("{}", csv.to_string());
        println!("--- in {:.2}s ---", t0.elapsed().as_secs_f64());
    }

    // Timed: one heavy competition cell.
    bench("competition/20users/60jobs/d3100", 1, 3, || {
        let c = SweepConfig {
            user_counts: vec![20],
            budgets: vec![12_000.0],
            gridlets: 60,
            ..SweepConfig::quick()
        };
        figs33_38(3_100.0, &c).len()
    });

    // Scaling metric: events/s with 40 brokers live, via GridSession.
    use gridsim::broker::{ExperimentSpec, Optimization};
    use gridsim::config::testbed::wwg_testbed;
    use gridsim::scenario::Scenario;
    use gridsim::session::GridSession;
    let scenario = Scenario::builder()
        .resources(wwg_testbed())
        .users(
            40,
            ExperimentSpec::task_farm(40, 10_000.0, 0.10)
                .deadline(3_100.0)
                .budget(12_000.0)
                .optimization(Optimization::Cost),
        )
        .seed(17)
        .build();
    let t0 = Instant::now();
    let report = GridSession::new(&scenario).run_to_completion();
    metric(
        "multi_user_events_per_sec(40 users)",
        report.events as f64 / t0.elapsed().as_secs_f64(),
        "events/s",
    );

    // Heterogeneous competition cell: the 40 users split across all four
    // DBC policies (per-user overrides), same market.
    let policies =
        [Optimization::Cost, Optimization::Time, Optimization::CostTime, Optimization::NoOpt];
    let mut builder = Scenario::builder().resources(wwg_testbed()).seed(17);
    for i in 0..40 {
        builder = builder.user(
            ExperimentSpec::task_farm(40, 10_000.0, 0.10)
                .deadline(3_100.0)
                .budget(12_000.0)
                .optimization(policies[i % policies.len()]),
        );
    }
    let scenario = builder.build();
    let t0 = Instant::now();
    let report = GridSession::new(&scenario).run_to_completion();
    metric(
        "heterogeneous_events_per_sec(40 users, 4 policies)",
        report.events as f64 / t0.elapsed().as_secs_f64(),
        "events/s",
    );
}
