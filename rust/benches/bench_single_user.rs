//! Regenerates the paper's single-user evaluation series (Figures 21–27)
//! and times the full sweep — the "one bench per table/figure" harness for
//! the §5.3 experiments. Prints the same rows the paper plots.

mod harness;

use gridsim::figures::{figs21_24, figs25_27, FigureConfig};
use harness::bench;
use std::time::Instant;

fn main() {
    println!("== bench_single_user: paper §5.3 (Figures 21–27) ==");

    // Representative sub-grid, printed like the paper's series.
    let cfg = FigureConfig {
        deadlines: vec![100.0, 1_100.0, 3_100.0],
        budgets: vec![6_000.0, 10_000.0, 14_000.0, 18_000.0, 22_000.0],
        gridlets: 200,
        ..FigureConfig::quick()
    };
    let t0 = Instant::now();
    let csv = figs21_24(&cfg);
    println!("--- Figs 21-24 series (deadline, budget, done, time, spent) ---");
    print!("{}", csv.to_string());
    println!(
        "--- {} cells in {:.2}s ---",
        cfg.deadlines.len() * cfg.budgets.len(),
        t0.elapsed().as_secs_f64()
    );

    println!("--- Fig 27 resource selection at deadline 3100 ---");
    let sel_cfg = FigureConfig {
        budgets: vec![6_000.0, 14_000.0, 22_000.0],
        gridlets: 200,
        ..FigureConfig::quick()
    };
    print!("{}", figs25_27(3_100.0, &sel_cfg).to_string());

    // Timed benches: one full-size simulation per paper cell class.
    let cell = |deadline: f64, budget: f64| {
        let c = FigureConfig {
            deadlines: vec![deadline],
            budgets: vec![budget],
            gridlets: 200,
            ..FigureConfig::quick()
        };
        figs21_24(&c).len()
    };
    bench("cell/tight-deadline-100", 1, 3, || cell(100.0, 22_000.0));
    bench("cell/medium-deadline-1100", 1, 3, || cell(1_100.0, 22_000.0));
    bench("cell/relaxed-deadline-3100", 1, 3, || cell(3_100.0, 22_000.0));
}
