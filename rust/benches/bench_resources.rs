//! Resource-model benchmarks (paper Table 1 / Figs 9 & 12 machinery at
//! scale): time- vs space-shared scheduling throughput in Gridlets/s.

mod harness;

use gridsim::gridsim::{
    gridlet::Gridlet, res_gridlet::ResGridlet, resource::LocalScheduler,
    space_shared::SpaceShared, time_shared::TimeShared, SpacePolicy,
};
use harness::{bench, metric};
use std::time::Instant;

/// Push `n` gridlets through a scheduler via its public event interface.
fn drive(sched: &mut dyn LocalScheduler, n: usize) -> usize {
    let mut now = 0.0;
    let mut done = 0;
    let mut submitted = 0;
    // Poisson-ish staggered arrivals, 4 per time unit.
    while done < n {
        let next_arrival =
            if submitted < n { submitted as f64 * 0.25 } else { f64::INFINITY };
        let next_completion = sched.next_completion(now).unwrap_or(f64::INFINITY);
        if next_arrival <= next_completion {
            now = next_arrival;
            let g = Gridlet::new(submitted, 50.0 + (submitted % 17) as f64, 0, 0);
            sched.submit(ResGridlet::new(g, now, submitted as u64), now);
            submitted += 1;
        } else {
            now = next_completion;
            done += sched.collect(now).len();
        }
    }
    done
}

fn main() {
    println!("== bench_resources: local scheduler throughput (Table 1 machinery) ==");
    let n = 20_000;

    bench("time_shared/4pe/20k-gridlets", 1, 5, || {
        let mut ts = TimeShared::new(4, 100.0);
        drive(&mut ts, n)
    });
    bench("space_shared_fcfs/4pe/20k-gridlets", 1, 5, || {
        let mut ss = SpaceShared::new(&[4], 100.0, SpacePolicy::Fcfs);
        drive(&mut ss, n)
    });
    bench("space_shared_sjf/4pe/20k-gridlets", 1, 5, || {
        let mut ss = SpaceShared::new(&[4], 100.0, SpacePolicy::Sjf);
        drive(&mut ss, n)
    });
    bench("space_shared_backfill/4pe/20k-gridlets", 1, 5, || {
        let mut ss = SpaceShared::new(&[4], 100.0, SpacePolicy::BackfillEasy);
        drive(&mut ss, n)
    });
    // Oversubscription stress: many concurrent gridlets sharing few PEs
    // (the Fig 8 share allocator dominates).
    bench("time_shared/2pe/oversubscribed", 1, 5, || {
        let mut ts = TimeShared::new(2, 1000.0);
        drive(&mut ts, 5_000)
    });

    let t0 = Instant::now();
    let mut ts = TimeShared::new(4, 100.0);
    let done = drive(&mut ts, 100_000);
    metric(
        "time_shared_gridlets_per_sec",
        done as f64 / t0.elapsed().as_secs_f64(),
        "gridlets/s",
    );
}
