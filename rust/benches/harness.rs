//! Minimal shared bench harness (no criterion in the image): warmup +
//! timed iterations with mean/min/max reporting, plus a snapshot recorder
//! that maintains the committed `BENCH_<date>.json` perf trajectory.

use std::time::Instant;

/// Run `f` for `iters` timed iterations after `warmup` untimed ones;
/// print a stable one-line summary.
#[allow(dead_code)]
pub fn bench<T>(name: &str, warmup: u32, iters: u32, mut f: impl FnMut() -> T) {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut samples = Vec::with_capacity(iters as usize);
    for _ in 0..iters {
        let t0 = Instant::now();
        std::hint::black_box(f());
        samples.push(t0.elapsed().as_secs_f64());
    }
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let min = samples.iter().cloned().fold(f64::INFINITY, f64::min);
    let max = samples.iter().cloned().fold(0.0f64, f64::max);
    println!(
        "bench {name:<44} mean {:>10.3} ms   min {:>10.3} ms   max {:>10.3} ms   ({iters} iters)",
        mean * 1e3,
        min * 1e3,
        max * 1e3
    );
}

/// Print a named scalar metric (events/s, gridlets/s, …).
#[allow(dead_code)]
pub fn metric(name: &str, value: f64, unit: &str) {
    println!("metric {name:<43} {value:>14.1} {unit}");
}

/// Directory the snapshot is written to: `$BENCH_SNAPSHOT_DIR` when set
/// (CI points this at a scratch dir), otherwise the repository root.
#[allow(dead_code)]
pub fn snapshot_dir() -> String {
    std::env::var("BENCH_SNAPSHOT_DIR")
        .unwrap_or_else(|_| concat!(env!("CARGO_MANIFEST_DIR"), "/..").to_string())
}

/// Collects metrics alongside the stdout report and merges them into the
/// dated machine-readable snapshot (`BENCH_<YYYY-MM-DD>.json`), so bench
/// numbers can be committed and diffed across revisions.
///
/// Snapshot shape (one file per date, shared by every bench binary):
///
/// ```json
/// {
///   "date": "2026-08-07",
///   "rev": "b91366d",
///   "cargo": "cargo 1.79.0",
///   "benches": { "bench_million": { "metrics": [ {"name", "value", "unit"} ] } }
/// }
/// ```
///
/// Guarantees: re-running one bench never clobbers another bench's entries
/// in the same-date file, and an unmeasured (`null`) value never replaces a
/// previously measured one — the trajectory only moves from null to real.
#[allow(dead_code)]
pub struct Recorder {
    bench: String,
    metrics: Vec<(String, Option<f64>, String)>,
}

#[allow(dead_code)]
impl Recorder {
    /// A recorder for one bench binary.
    pub fn new(bench: &str) -> Recorder {
        Recorder { bench: bench.into(), metrics: Vec::new() }
    }

    /// Print via [`metric`] and keep the value for the snapshot.
    pub fn metric(&mut self, name: &str, value: f64, unit: &str) {
        metric(name, value, unit);
        self.metrics.push((name.into(), Some(value), unit.into()));
    }

    /// Record a metric that may be unavailable in this environment (e.g.
    /// peak RSS off-Linux). `None` is written as JSON `null` — unless the
    /// snapshot already carries a measured value for it, which is kept.
    pub fn maybe_metric(&mut self, name: &str, value: Option<f64>, unit: &str) {
        match value {
            Some(v) => self.metric(name, v, unit),
            None => {
                println!("metric {name:<43} {:>14} {unit}", "null");
                self.metrics.push((name.into(), None, unit.into()));
            }
        }
    }

    /// Merge this run into `dir/BENCH_<date>.json`; returns the path
    /// written. Existing same-date entries for other benches are preserved;
    /// see the type docs for the never-null-over-measured rule.
    pub fn write_snapshot(&self, dir: &str) -> std::io::Result<String> {
        use gridsim::util::json::{self, Value};
        let date = today_utc();
        let path = format!("{dir}/BENCH_{date}.json");

        let existing = std::fs::read_to_string(&path)
            .ok()
            .and_then(|text| json::parse(&text).ok())
            .map(normalize_snapshot)
            .unwrap_or_default();

        // Previously measured values for this bench (the null guard).
        let prior: Vec<(String, Value)> = existing
            .iter()
            .find(|(b, _)| b == &self.bench)
            .and_then(|(_, v)| v.get("metrics"))
            .and_then(Value::as_arr)
            .map(|arr| {
                arr.iter()
                    .filter_map(|m| {
                        let name = m.get("name")?.as_str()?.to_string();
                        let value = m.get("value")?.clone();
                        Some((name, value))
                    })
                    .collect()
            })
            .unwrap_or_default();

        let metrics: Vec<Value> = self
            .metrics
            .iter()
            .map(|(n, v, u)| {
                let value = match v {
                    Some(x) => Value::Num(*x),
                    // Keep a measured prior value instead of nulling it.
                    None => prior
                        .iter()
                        .find(|(pn, pv)| pn == n && pv.as_f64().is_some())
                        .map(|(_, pv)| pv.clone())
                        .unwrap_or(Value::Null),
                };
                Value::obj(vec![
                    ("name", Value::str(n.clone())),
                    ("value", value),
                    ("unit", Value::str(u.clone())),
                ])
            })
            .collect();

        let mut benches: Vec<(String, Value)> = existing;
        let entry = Value::obj(vec![("metrics", Value::Arr(metrics))]);
        match benches.iter_mut().find(|(b, _)| b == &self.bench) {
            Some((_, v)) => *v = entry,
            None => benches.push((self.bench.clone(), entry)),
        }

        let record = Value::obj(vec![
            ("date", Value::str(date.clone())),
            ("rev", Value::str(git_rev())),
            ("cargo", Value::str(cargo_version())),
            (
                "benches",
                Value::Obj(benches),
            ),
        ]);
        std::fs::write(&path, json::to_string_pretty(&record) + "\n")?;
        Ok(path)
    }
}

/// Existing snapshot → `(bench name, entry)` list. Handles both the merged
/// shape (`benches` object) and the legacy flat one-bench shape
/// (`{"bench": ..., "metrics": [...]}`), so the first run after the format
/// change upgrades old files instead of losing them.
#[allow(dead_code)]
fn normalize_snapshot(v: gridsim::util::json::Value) -> Vec<(String, gridsim::util::json::Value)> {
    use gridsim::util::json::Value;
    if let Some(Value::Obj(benches)) = v.get("benches") {
        return benches.clone();
    }
    if let (Some(bench), Some(metrics)) = (v.get("bench").and_then(Value::as_str), v.get("metrics"))
    {
        let mut fields = vec![("metrics".to_string(), metrics.clone())];
        if let Some(note) = v.get("note") {
            fields.push(("note".to_string(), note.clone()));
        }
        return vec![(bench.to_string(), Value::Obj(fields))];
    }
    Vec::new()
}

/// Short git revision of the working tree, or `"unknown"` outside a repo.
#[allow(dead_code)]
fn git_rev() -> String {
    run_for_line("git", &["rev-parse", "--short", "HEAD"])
}

/// `cargo --version` one-liner, or `"unknown"`.
#[allow(dead_code)]
fn cargo_version() -> String {
    run_for_line("cargo", &["--version"])
}

#[allow(dead_code)]
fn run_for_line(cmd: &str, args: &[&str]) -> String {
    std::process::Command::new(cmd)
        .args(args)
        .output()
        .ok()
        .filter(|o| o.status.success())
        .and_then(|o| String::from_utf8(o.stdout).ok())
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .unwrap_or_else(|| "unknown".to_string())
}

/// Peak resident set size of this process in bytes (Linux `VmHWM`), `None`
/// on other platforms or unreadable `/proc`.
#[allow(dead_code)]
pub fn peak_rss_bytes() -> Option<f64> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    let line = status.lines().find(|l| l.starts_with("VmHWM:"))?;
    let kb: f64 = line.split_whitespace().nth(1)?.parse().ok()?;
    Some(kb * 1024.0)
}

/// Civil date (UTC) from the system clock, without a date dependency
/// (Howard Hinnant's `civil_from_days`).
#[allow(dead_code)]
fn today_utc() -> String {
    let secs = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0);
    let z = (secs / 86_400) as i64 + 719_468;
    let era = z.div_euclid(146_097);
    let doe = z.rem_euclid(146_097);
    let yoe = (doe - doe / 1_460 + doe / 36_524 - doe / 146_096) / 365;
    let doy = doe - (365 * yoe + yoe / 4 - yoe / 100);
    let mp = (5 * doy + 2) / 153;
    let d = doy - (153 * mp + 2) / 5 + 1;
    let m = if mp < 10 { mp + 3 } else { mp - 9 };
    let y = yoe + era * 400 + i64::from(m <= 2);
    format!("{y:04}-{m:02}-{d:02}")
}
