//! Minimal shared bench harness (no criterion in the image): warmup +
//! timed iterations with mean/min/max reporting.

use std::time::Instant;

/// Run `f` for `iters` timed iterations after `warmup` untimed ones;
/// print a stable one-line summary.
#[allow(dead_code)]
pub fn bench<T>(name: &str, warmup: u32, iters: u32, mut f: impl FnMut() -> T) {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut samples = Vec::with_capacity(iters as usize);
    for _ in 0..iters {
        let t0 = Instant::now();
        std::hint::black_box(f());
        samples.push(t0.elapsed().as_secs_f64());
    }
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let min = samples.iter().cloned().fold(f64::INFINITY, f64::min);
    let max = samples.iter().cloned().fold(0.0f64, f64::max);
    println!(
        "bench {name:<44} mean {:>10.3} ms   min {:>10.3} ms   max {:>10.3} ms   ({iters} iters)",
        mean * 1e3,
        min * 1e3,
        max * 1e3
    );
}

/// Print a named scalar metric (events/s, gridlets/s, …).
#[allow(dead_code)]
pub fn metric(name: &str, value: f64, unit: &str) {
    println!("metric {name:<43} {value:>14.1} {unit}");
}

/// Collects metrics alongside the stdout report and writes them as a
/// dated machine-readable snapshot (`BENCH_<YYYY-MM-DD>.json`), so bench
/// numbers can be committed and diffed across revisions.
#[allow(dead_code)]
pub struct Recorder {
    bench: String,
    metrics: Vec<(String, f64, String)>,
}

#[allow(dead_code)]
impl Recorder {
    /// A recorder for one bench binary.
    pub fn new(bench: &str) -> Recorder {
        Recorder { bench: bench.into(), metrics: Vec::new() }
    }

    /// Print via [`metric`] and keep the value for the snapshot.
    pub fn metric(&mut self, name: &str, value: f64, unit: &str) {
        metric(name, value, unit);
        self.metrics.push((name.into(), value, unit.into()));
    }

    /// Write `BENCH_<date>.json` into `dir`; returns the path written.
    pub fn write_snapshot(&self, dir: &str) -> std::io::Result<String> {
        use gridsim::util::json::{self, Value};
        let date = today_utc();
        let record = Value::obj(vec![
            ("bench", Value::str(self.bench.clone())),
            ("date", Value::str(date.clone())),
            (
                "metrics",
                Value::Arr(
                    self.metrics
                        .iter()
                        .map(|(n, v, u)| {
                            Value::obj(vec![
                                ("name", Value::str(n.clone())),
                                ("value", (*v).into()),
                                ("unit", Value::str(u.clone())),
                            ])
                        })
                        .collect(),
                ),
            ),
        ]);
        let path = format!("{dir}/BENCH_{date}.json");
        std::fs::write(&path, json::to_string_pretty(&record) + "\n")?;
        Ok(path)
    }
}

/// Civil date (UTC) from the system clock, without a date dependency
/// (Howard Hinnant's `civil_from_days`).
#[allow(dead_code)]
fn today_utc() -> String {
    let secs = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0);
    let z = (secs / 86_400) as i64 + 719_468;
    let era = z.div_euclid(146_097);
    let doe = z.rem_euclid(146_097);
    let yoe = (doe - doe / 1_460 + doe / 36_524 - doe / 146_096) / 365;
    let doy = doe - (365 * yoe + yoe / 4 - yoe / 100);
    let mp = (5 * doy + 2) / 153;
    let d = doy - (153 * mp + 2) / 5 + 1;
    let m = if mp < 10 { mp + 3 } else { mp - 9 };
    let y = yoe + era * 400 + i64::from(m <= 2);
    format!("{y:04}-{m:02}-{d:02}")
}
