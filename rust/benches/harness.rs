//! Minimal shared bench harness (no criterion in the image): warmup +
//! timed iterations with mean/min/max reporting.

use std::time::Instant;

/// Run `f` for `iters` timed iterations after `warmup` untimed ones;
/// print a stable one-line summary.
#[allow(dead_code)]
pub fn bench<T>(name: &str, warmup: u32, iters: u32, mut f: impl FnMut() -> T) {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut samples = Vec::with_capacity(iters as usize);
    for _ in 0..iters {
        let t0 = Instant::now();
        std::hint::black_box(f());
        samples.push(t0.elapsed().as_secs_f64());
    }
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let min = samples.iter().cloned().fold(f64::INFINITY, f64::min);
    let max = samples.iter().cloned().fold(0.0f64, f64::max);
    println!(
        "bench {name:<44} mean {:>10.3} ms   min {:>10.3} ms   max {:>10.3} ms   ({iters} iters)",
        mean * 1e3,
        min * 1e3,
        max * 1e3
    );
}

/// Print a named scalar metric (events/s, gridlets/s, …).
#[allow(dead_code)]
pub fn metric(name: &str, value: f64, unit: &str) {
    println!("metric {name:<43} {value:>14.1} {unit}");
}
