"""L2 checks: the jitted model functions and their AOT lowering.

Covers the artifact ABI (shapes/ordering the Rust runtime relies on) and
lowering to HLO text on this image's jax/xla_extension combination.
"""

import numpy as np
import pytest

from compile import aot, model
from compile.kernels.advisor import R as AR
from compile.kernels.forecast import J as FJ
from compile.kernels.forecast import R as FR


def test_advisor_step_shapes_and_round():
    rate = np.zeros(AR, np.float32)
    cost = np.ones(AR, np.float32)
    active = np.zeros(AR, np.float32)
    rate[0], cost[0], active[0] = 100.0, 0.01, 1.0
    (counts,) = model.advisor_step(
        rate, cost, active,
        np.float32(10.0), np.float32(1e9), np.float32(100.0), np.float32(5.0),
    )
    counts = np.asarray(counts)
    assert counts.shape == (AR,)
    np.testing.assert_allclose(counts, np.round(counts))
    assert counts[0] == 5


def test_forecast_batch_next_event_reduction():
    remaining = np.zeros((FR, FJ), np.float32)
    active = np.zeros((FR, FJ), np.float32)
    remaining[0, :3] = [3.0, 5.5, 9.5]
    active[0, :3] = 1.0
    mips = np.zeros(FR, np.float32); mips[0] = 1.0
    pes = np.ones(FR, np.float32); pes[0] = 2.0
    avail = np.ones(FR, np.float32)
    comp, rate, next_event = model.forecast_batch(remaining, active, mips, pes, avail)
    assert np.asarray(comp).shape == (FR, FJ)
    assert np.asarray(rate).shape == (FR, FJ)
    next_event = np.asarray(next_event)
    assert next_event.shape == (FR,)
    np.testing.assert_allclose(next_event[0], 3.0)
    # Idle resources report the sentinel (huge) value.
    assert (next_event[1:] > 1e30).all()


def test_example_args_match_runtime_abi():
    adv = model.advisor_example_args()
    assert [a.shape for a in adv] == [(AR,)] * 3 + [()] * 4
    fc = model.forecast_example_args()
    assert [a.shape for a in fc] == [(FR, FJ), (FR, FJ), (FR,), (FR,), (FR,)]


@pytest.mark.parametrize("name", list(aot.ARTIFACTS))
def test_aot_lowering_produces_hlo_text(name, tmp_path):
    fn, example_args = aot.ARTIFACTS[name]
    import jax

    lowered = jax.jit(fn).lower(*example_args())
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    assert "ENTRY" in text
    # Interpret-mode pallas must not leave TPU custom-calls behind.
    assert "tpu_custom_call" not in text


def test_build_writes_both_artifacts(tmp_path):
    aot.build(str(tmp_path))
    for name in aot.ARTIFACTS:
        path = tmp_path / name
        assert path.exists()
        assert path.stat().st_size > 1000
