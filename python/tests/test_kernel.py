"""L1 correctness: Pallas kernels vs the pure oracles in ref.py.

This is the core correctness signal for the AOT artifacts — the Rust runtime
executes exactly these computations (same HLO), so kernel == ref here plus
native == xla on the Rust side pins all four implementations together.
"""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels.advisor import R as AR
from compile.kernels.advisor import advisor_kernel
from compile.kernels.forecast import J as FJ
from compile.kernels.forecast import R as FR
from compile.kernels.forecast import forecast_kernel
from compile.kernels.ref import advisor_ref, forecast_ref


# ---------------------------------------------------------------- advisor --


def run_advisor(rate, cost, active, t, b, avg, jobs):
    got = np.asarray(
        advisor_kernel(
            np.asarray(rate, np.float32),
            np.asarray(cost, np.float32),
            np.asarray(active, np.float32),
            np.float32(t),
            np.float32(b),
            np.float32(avg),
            np.float32(jobs),
        )
    )
    want = advisor_ref(
        np.asarray(rate, np.float64),
        np.asarray(cost, np.float64),
        np.asarray(active, np.float64),
        t,
        b,
        avg,
        jobs,
    )
    return got, want


def pad(xs, fill=0.0):
    out = np.full(AR, fill, dtype=np.float64)
    out[: len(xs)] = xs
    return out


def test_advisor_fills_cheapest_first():
    rate = pad([50.0, 1000.0])
    cost = pad([0.01, 0.05], fill=1.0)
    active = pad([1.0, 1.0])
    got, want = run_advisor(rate, cost, active, 10.0, 1e9, 100.0, 8)
    np.testing.assert_allclose(got, want)
    assert got[0] == 5 and got[1] == 3


def test_advisor_budget_truncation():
    rate = pad([20.0, 1000.0])
    cost = pad([0.01, 0.10], fill=1.0)
    active = pad([1.0, 1.0])
    got, want = run_advisor(rate, cost, active, 10.0, 25.0, 100.0, 50)
    np.testing.assert_allclose(got, want)
    assert got.tolist()[:2] == [2.0, 2.0]


def test_advisor_zero_time_or_budget():
    rate = pad([100.0])
    cost = pad([0.01], fill=1.0)
    active = pad([1.0])
    got, want = run_advisor(rate, cost, active, 0.0, 1e9, 100.0, 10)
    np.testing.assert_allclose(got, want)
    assert got.sum() == 0
    got, want = run_advisor(rate, cost, active, 10.0, 0.0, 100.0, 10)
    np.testing.assert_allclose(got, want)
    assert got.sum() == 0


def test_advisor_padding_lanes_stay_zero():
    rate = np.full(AR, 1e6)
    cost = np.zeros(AR)  # free resources — would absorb everything if active
    active = pad([1.0])
    got, _ = run_advisor(rate, cost, active, 100.0, 1e9, 100.0, 17)
    assert got[1:].sum() == 0
    assert got[0] == 17


@st.composite
def advisor_cases(draw):
    n = draw(st.integers(min_value=1, max_value=AR))
    # Costs ascending (the broker sorts); strictly separated enough that f32
    # and f64 agree on the greedy (avoid knife-edge floor() disagreements by
    # using "nice" grid values).
    costs = sorted(
        draw(
            st.lists(
                st.integers(min_value=1, max_value=500).map(lambda x: x / 1000.0),
                min_size=n,
                max_size=n,
            )
        )
    )
    rates = draw(
        st.lists(st.integers(min_value=0, max_value=4000), min_size=n, max_size=n)
    )
    t = draw(st.integers(min_value=0, max_value=4000))
    b = draw(st.integers(min_value=0, max_value=30000))
    avg = draw(st.integers(min_value=50, max_value=20000))
    jobs = draw(st.integers(min_value=0, max_value=300))
    return n, costs, rates, float(t), float(b), float(avg), float(jobs)


@settings(max_examples=150, deadline=None)
@given(advisor_cases())
def test_advisor_matches_ref_hypothesis(case):
    n, costs, rates, t, b, avg, jobs = case
    rate = pad(rates)
    cost = pad(costs, fill=1.0)
    active = pad([1.0] * n)
    got, want = run_advisor(rate, cost, active, t, b, avg, jobs)
    # f32 vs f64 can disagree by one whole job at exact floor() boundaries;
    # allow that slack while requiring structural agreement.
    np.testing.assert_allclose(got, want, atol=1.0)
    assert got.sum() <= jobs + 1e-6
    # Budget respected (with one-job f32 slack at each lane).
    spend = float((got * cost * avg).sum())
    assert spend <= b + float((cost * avg).max()) + 1e-3


@settings(max_examples=60, deadline=None)
@given(advisor_cases())
def test_advisor_invariants(case):
    n, costs, rates, t, b, avg, jobs = case
    rate = pad(rates)
    cost = pad(costs, fill=1.0)
    active = pad([1.0] * n)
    got, _ = run_advisor(rate, cost, active, t, b, avg, jobs)
    # Whole, non-negative counts; nothing on padding lanes.
    assert (got >= 0).all()
    np.testing.assert_allclose(got, np.round(got))
    assert got[n:].sum() == 0
    # Per-lane deadline capacity respected.
    capacity = np.floor(np.float32(rate) * np.float32(t) / np.float32(max(avg, 1e-9)))
    assert (got <= capacity[: len(got)] + 1e-6).all()


# --------------------------------------------------------------- forecast --


def run_forecast(remaining, active, mips, pes, avail):
    comp, rate = forecast_kernel(
        np.asarray(remaining, np.float32),
        np.asarray(active, np.float32),
        np.asarray(mips, np.float32),
        np.asarray(pes, np.float32),
        np.asarray(avail, np.float32),
    )
    comp_ref, rate_ref = forecast_ref(
        np.asarray(remaining, np.float64),
        np.asarray(active, np.float64),
        np.asarray(mips, np.float64),
        np.asarray(pes, np.float64),
        np.asarray(avail, np.float64),
    )
    return np.asarray(comp), np.asarray(rate), comp_ref, rate_ref


def dense(rows):
    remaining = np.zeros((FR, FJ))
    active = np.zeros((FR, FJ))
    for r, vals in enumerate(rows):
        remaining[r, : len(vals)] = vals
        active[r, : len(vals)] = 1.0
    return remaining, active


def test_forecast_paper_fig9_shares():
    # The Table 1 moment at t=7: G1 (3 MI left) alone on PE1 at full rate;
    # G2 (5.5) and G3 (9.5) share PE2 at half rate. 2 PEs x 1 MIPS.
    remaining, active = dense([[3.0, 5.5, 9.5]])
    mips = np.zeros(FR)
    mips[0] = 1.0
    pes = np.ones(FR)
    pes[0] = 2.0
    avail = np.ones(FR)
    comp, rate, comp_ref, rate_ref = run_forecast(remaining, active, mips, pes, avail)
    np.testing.assert_allclose(rate[0, :3], [1.0, 0.5, 0.5])
    np.testing.assert_allclose(comp[0, :3], [3.0, 11.0, 19.0])
    np.testing.assert_allclose(rate, rate_ref, rtol=1e-6)
    np.testing.assert_allclose(comp, comp_ref, rtol=1e-6)


def test_forecast_underloaded_full_rate():
    remaining, active = dense([[100.0, 200.0]])
    mips = np.full(FR, 10.0)
    pes = np.full(FR, 4.0)
    avail = np.ones(FR)
    comp, rate, comp_ref, rate_ref = run_forecast(remaining, active, mips, pes, avail)
    np.testing.assert_allclose(rate[0, :2], [10.0, 10.0])
    np.testing.assert_allclose(comp[0, :2], [10.0, 20.0])
    np.testing.assert_allclose(rate, rate_ref, rtol=1e-6)


def test_forecast_availability_scales():
    remaining, active = dense([[100.0]])
    mips = np.full(FR, 10.0)
    pes = np.ones(FR)
    avail = np.full(FR, 0.5)
    comp, rate, _, _ = run_forecast(remaining, active, mips, pes, avail)
    np.testing.assert_allclose(rate[0, 0], 5.0)
    np.testing.assert_allclose(comp[0, 0], 20.0)


def test_forecast_inactive_slots_zero():
    remaining, active = dense([[1.0]])
    mips = np.ones(FR)
    pes = np.ones(FR)
    avail = np.ones(FR)
    comp, rate, _, _ = run_forecast(remaining, active, mips, pes, avail)
    assert rate[0, 1:].sum() == 0
    assert comp[1:].sum() == 0


@st.composite
def forecast_cases(draw):
    rows = []
    n_res = draw(st.integers(min_value=1, max_value=FR))
    for _ in range(n_res):
        n_jobs = draw(st.integers(min_value=0, max_value=24))
        rows.append(
            draw(
                st.lists(
                    st.floats(min_value=0.5, max_value=1e5),
                    min_size=n_jobs,
                    max_size=n_jobs,
                )
            )
        )
    mips = draw(
        st.lists(
            st.floats(min_value=1.0, max_value=1000.0), min_size=FR, max_size=FR
        )
    )
    pes = draw(st.lists(st.integers(min_value=1, max_value=32), min_size=FR, max_size=FR))
    avail = draw(
        st.lists(st.floats(min_value=0.05, max_value=1.0), min_size=FR, max_size=FR)
    )
    return rows, mips, pes, avail


@settings(max_examples=80, deadline=None)
@given(forecast_cases())
def test_forecast_matches_ref_hypothesis(case):
    rows, mips, pes, avail = case
    remaining, active = dense(rows)
    comp, rate, comp_ref, rate_ref = run_forecast(
        remaining, active, np.array(mips), np.array(pes, float), np.array(avail)
    )
    np.testing.assert_allclose(rate, rate_ref, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(comp, comp_ref, rtol=1e-5, atol=1e-4)


@settings(max_examples=40, deadline=None)
@given(forecast_cases())
def test_forecast_conservation(case):
    """Fig 8 invariant: total allocated rate never exceeds aggregate MIPS,
    and equals it when the resource is oversubscribed."""
    rows, mips, pes, avail = case
    remaining, active = dense(rows)
    _, rate, _, _ = run_forecast(
        remaining, active, np.array(mips), np.array(pes, float), np.array(avail)
    )
    for r, vals in enumerate(rows):
        total = rate[r].sum()
        agg = mips[r] * avail[r] * pes[r]
        assert total <= agg * (1 + 1e-5) + 1e-6
        if len(vals) >= pes[r]:
            used = mips[r] * avail[r] * min(len(vals), pes[r])
            np.testing.assert_allclose(total, used, rtol=1e-5)
