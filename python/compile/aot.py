"""AOT compile path: lower the L2 jitted functions to HLO **text** artifacts
for the Rust PJRT runtime.

Run once by ``make artifacts``:

    cd python && python -m compile.aot --out ../artifacts

Interchange is HLO text, not serialized ``HloModuleProto`` — jax >= 0.5
emits protos with 64-bit instruction ids that the image's xla_extension
0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser reassigns ids.
Lowered with ``return_tuple=True`` so the Rust side always unwraps a tuple.
"""

from __future__ import annotations

import argparse
import os

import jax
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


ARTIFACTS = {
    "advisor.hlo.txt": (model.advisor_step, model.advisor_example_args),
    "forecast.hlo.txt": (model.forecast_batch, model.forecast_example_args),
}


def build(out_dir: str) -> None:
    os.makedirs(out_dir, exist_ok=True)
    for name, (fn, example_args) in ARTIFACTS.items():
        lowered = jax.jit(fn).lower(*example_args())
        text = to_hlo_text(lowered)
        path = os.path.join(out_dir, name)
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {len(text)} chars to {path}")


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out", default="../artifacts", help="artifacts directory")
    args = parser.parse_args()
    build(args.out)


if __name__ == "__main__":
    main()
