"""L2: the JAX compute graph around the L1 Pallas kernels.

Two jitted entry points, both AOT-lowered to HLO text by ``aot.py``:

* ``advisor_step`` — one broker scheduling decision (Fig 20 steps a-c).
  Wraps the Pallas advisor kernel; masks padding lanes so garbage in unused
  slots can never produce allocations.
* ``forecast_batch`` — batched Fig 8 completion forecast over [R, J].
  Wraps the Pallas forecast kernel and also reduces to the per-resource
  earliest completion (the resource simulator's next-interrupt time), so the
  Rust side gets both the dense matrix and the reduction from one execution.

The signatures here define the artifact ABI; ``rust/src/runtime/pjrt.rs``
must feed literals in exactly this order.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels.advisor import R as ADVISOR_R
from .kernels.advisor import advisor_kernel
from .kernels.forecast import J as FORECAST_J
from .kernels.forecast import R as FORECAST_R
from .kernels.forecast import forecast_kernel


def advisor_step(rate, cost_per_mi, active, time_left, budget_left, avg_job_mi, jobs):
    """Desired whole-job allocation per resource; zeros in padding lanes.

    Args (f32): rate[R], cost_per_mi[R], active[R] in {0,1}; scalars
    time_left, budget_left, avg_job_mi, jobs.
    Returns: (counts[R],)
    """
    counts = advisor_kernel(
        rate, cost_per_mi, active, time_left, budget_left, avg_job_mi, jobs
    )
    # Belt-and-braces: padding lanes carry no allocation and counts are
    # non-negative whole numbers.
    counts = jnp.maximum(counts, 0.0) * active
    return (jnp.round(counts),)


def forecast_batch(remaining_mi, active, mips, num_pe, avail):
    """Completion forecast.

    Args (f32): remaining_mi[R,J], active[R,J], mips[R], num_pe[R], avail[R].
    Returns: (completion[R,J], rate[R,J], next_event[R]) where next_event is
    the earliest completion per resource (+inf-free: 3.4e38 sentinel for
    idle resources, which the Rust wrapper masks out).
    """
    completion, rate = forecast_kernel(remaining_mi, active, mips, num_pe, avail)
    big = jnp.float32(3.4e38)
    masked = jnp.where(active > 0.0, completion, big)
    next_event = jnp.min(masked, axis=1)
    return (completion, rate, next_event)


def advisor_example_args():
    """Example (shape-defining) arguments for AOT lowering."""
    vec = jax.ShapeDtypeStruct((ADVISOR_R,), jnp.float32)
    scalar = jax.ShapeDtypeStruct((), jnp.float32)
    return (vec, vec, vec, scalar, scalar, scalar, scalar)


def forecast_example_args():
    mat = jax.ShapeDtypeStruct((FORECAST_R, FORECAST_J), jnp.float32)
    vec = jax.ShapeDtypeStruct((FORECAST_R,), jnp.float32)
    return (mat, mat, vec, vec, vec)
