"""L1 Pallas kernel: the DBC cost-optimization schedule advisor.

Vectorized form of the paper's Fig 20 greedy (see ``ref.advisor_ref``). The
sequential "walk resources cheapest-first" is replaced by two prefix-sum
passes, both computed as a strictly-lower-triangular ones matmul so the scan
runs on the MXU systolic array rather than as a serial loop:

1. capacity pass — how many of the ``jobs`` remain for resource *r* after
   all cheaper resources took their deadline capacity;
2. budget pass — truncate by what the remaining budget affords at *r*'s
   price after cheaper resources spent theirs.

Exactness: inputs are sorted by ascending cost/MI, so once the budget
truncates resource *k*, the leftover is smaller than the per-job cost of
every later resource — neither the spilled jobs nor the leftover budget can
change any later allocation. The two-pass result therefore equals the
sequential greedy (property-tested in python/tests and rust/tests).

TPU notes (§Hardware-Adaptation in DESIGN.md): R=16 keeps every operand in
VMEM; the two R×R triangular matmuls are MXU work; everything else is
elementwise VPU math. Lowered with ``interpret=True`` — the CPU PJRT client
cannot execute Mosaic custom-calls.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Fixed resource-axis padding; must match rust/src/runtime/pjrt.rs::ADVISOR_R.
R = 16


def _advisor_kernel(
    rate_ref,
    cost_ref,
    active_ref,
    time_ref,
    budget_ref,
    avg_ref,
    jobs_ref,
    out_ref,
):
    rate = rate_ref[...]
    cost_per_mi = cost_ref[...]
    active = active_ref[...]
    time_left = time_ref[0]
    budget_left = budget_ref[0]
    avg = jnp.maximum(avg_ref[0], 1e-9)
    jobs = jobs_ref[0]

    # Strictly-lower-triangular ones matrix: exclusive prefix sums as a
    # matmul (the MXU does the scan).
    row = jax.lax.broadcasted_iota(jnp.float32, (R, R), 0)
    col = jax.lax.broadcasted_iota(jnp.float32, (R, R), 1)
    tri = (row > col).astype(jnp.float32)

    # Step b (Fig 20): per-resource deadline capacity in whole jobs.
    capacity = jnp.floor(jnp.maximum(rate, 0.0) * time_left / avg * (1.0 + 1e-6) + 1e-6) * active
    cost_per_job = cost_per_mi * avg

    # Pass 1 — capacity-limited greedy via exclusive prefix of capacities.
    prefix_jobs = tri @ capacity
    take = jnp.clip(jobs - prefix_jobs, 0.0, capacity)

    # Pass 2 — budget truncation via exclusive prefix of planned spending.
    spend = take * cost_per_job
    prefix_cost = tri @ spend
    left = jnp.maximum(budget_left, 0.0) - prefix_cost
    # Relative epsilon mirrors the native advisor: exact-budget corners
    # (B-factor = 1) must not floor 0.999999… down to zero jobs.
    afford = jnp.where(
        cost_per_job > 0.0,
        jnp.floor(
            jnp.maximum(left, 0.0)
            / jnp.where(cost_per_job > 0.0, cost_per_job, 1.0)
            * (1.0 + 1e-6)
            + 1e-6
        ),
        jnp.inf,
    )
    out_ref[...] = jnp.minimum(take, afford) * active


def advisor_kernel(rate, cost_per_mi, active, time_left, budget_left, avg_job_mi, jobs):
    """Invoke the Pallas advisor kernel on ``[R]`` vectors + scalars."""
    assert rate.shape == (R,), rate.shape
    scalars = [
        jnp.reshape(x, (1,)).astype(jnp.float32)
        for x in (time_left, budget_left, avg_job_mi, jobs)
    ]
    return pl.pallas_call(
        _advisor_kernel,
        out_shape=jax.ShapeDtypeStruct((R,), jnp.float32),
        interpret=True,
    )(
        rate.astype(jnp.float32),
        cost_per_mi.astype(jnp.float32),
        active.astype(jnp.float32),
        *scalars,
    )
