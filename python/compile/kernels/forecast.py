"""L1 Pallas kernel: batched time-shared PE-share allocation + completion
forecast (paper Fig 8) over a ``[R, J]`` (resource × job-slot) tile.

Share rule, per resource with ``n`` active Gridlets on ``p`` PEs:
  * ``n <= p``      → every Gridlet runs at the PE's full effective MIPS;
  * ``n >  p``      → ``min_per = n // p``, ``extra = n % p``; the first
    ``(p - extra) * min_per`` Gridlets (arrival order) run at
    ``eff / min_per``, the rest at ``eff / (min_per + 1)``.

The arrival-order bucketing uses each active slot's rank (an exclusive
cumulative sum of the activity mask along J) — elementwise VPU math, no
gather/scatter. The whole [16, 256] tile (five f32 operands ≈ 80 KiB) sits
comfortably in VMEM as a single block; lowered with ``interpret=True`` for
the CPU PJRT client.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Fixed shapes; must match rust/src/runtime/pjrt.rs::FORECAST_R / FORECAST_J.
R = 16
J = 256


def _forecast_kernel(remaining_ref, active_ref, mips_ref, pes_ref, avail_ref, comp_ref, rate_ref):
    remaining = remaining_ref[...]
    active = active_ref[...]
    eff = (mips_ref[...] * avail_ref[...])[:, None]  # [R, 1]
    p = pes_ref[...][:, None]  # [R, 1]

    n = jnp.sum(active, axis=1, keepdims=True)  # active Gridlets per resource
    # Fig 8 bucket parameters (guard p >= 1 to avoid div-by-zero on padding).
    p_safe = jnp.maximum(p, 1.0)
    min_per = jnp.floor(n / p_safe)
    extra = n - min_per * p_safe
    max_count = (p_safe - extra) * min_per
    # 0-based arrival rank of each active slot (exclusive cumsum of mask).
    rank = jnp.cumsum(active, axis=1) - active
    full_rate = eff
    shared_rate = jnp.where(
        rank < max_count,
        eff / jnp.maximum(min_per, 1.0),
        eff / jnp.maximum(min_per + 1.0, 1.0),
    )
    rate = jnp.where(n <= p_safe, full_rate, shared_rate) * active
    rate_ref[...] = rate
    comp_ref[...] = jnp.where(rate > 0.0, remaining / jnp.maximum(rate, 1e-30), 0.0)


def forecast_kernel(remaining_mi, active, mips, num_pe, avail):
    """Invoke the Pallas forecast kernel.

    Returns ``(completion[R,J], rate[R,J])`` — times are relative to "now";
    inactive slots are zero.
    """
    assert remaining_mi.shape == (R, J), remaining_mi.shape
    return pl.pallas_call(
        _forecast_kernel,
        out_shape=(
            jax.ShapeDtypeStruct((R, J), jnp.float32),
            jax.ShapeDtypeStruct((R, J), jnp.float32),
        ),
        interpret=True,
    )(
        remaining_mi.astype(jnp.float32),
        active.astype(jnp.float32),
        mips.astype(jnp.float32),
        num_pe.astype(jnp.float32),
        avail.astype(jnp.float32),
    )
