"""Pure-jnp / plain-Python oracles for the L1 Pallas kernels.

These are the *simplest obviously-correct* implementations of the paper's two
numeric inner loops:

* ``advisor_ref`` — the DBC cost-optimization schedule advisor (paper Fig 20
  steps a-c): sequential greedy over resources sorted by ascending G$/MI.
* ``forecast_ref`` — the time-shared PE-share allocation + completion-time
  forecast (paper Fig 8), one resource at a time.

pytest (and hypothesis) compare the Pallas kernels against these, and the
Rust ``NativeAdvisor`` mirrors ``advisor_ref`` exactly, so all four
implementations are pinned to the same semantics.
"""

from __future__ import annotations

import numpy as np


def advisor_ref(
    rate: np.ndarray,
    cost_per_mi: np.ndarray,
    active: np.ndarray,
    time_left: float,
    budget_left: float,
    avg_job_mi: float,
    jobs: float,
) -> np.ndarray:
    """Sequential greedy allocation (resources must be cost-sorted).

    Returns the number of jobs per resource (float array, whole numbers).
    """
    r = len(rate)
    out = np.zeros(r, dtype=np.float64)
    remaining_jobs = float(jobs)
    remaining_budget = max(float(budget_left), 0.0)
    avg = max(float(avg_job_mi), 1e-9)
    t = max(float(time_left), 0.0)
    for i in range(r):
        if not active[i]:
            continue
        capacity = np.floor(max(rate[i], 0.0) * t / avg)
        cost_per_job = cost_per_mi[i] * avg
        if cost_per_job <= 0.0:
            affordable = np.inf
        else:
            affordable = np.floor(remaining_budget / cost_per_job)
        n = min(capacity, remaining_jobs, affordable)
        n = max(n, 0.0)
        out[i] = n
        remaining_jobs -= n
        remaining_budget -= n * cost_per_job
        if remaining_jobs <= 0:
            break
    return out


def forecast_ref(
    remaining_mi: np.ndarray,  # [R, J]
    active: np.ndarray,  # [R, J] in {0,1}
    mips: np.ndarray,  # [R]
    num_pe: np.ndarray,  # [R]
    avail: np.ndarray,  # [R]
) -> tuple[np.ndarray, np.ndarray]:
    """Fig 8 share rates and completion times, looped per resource.

    Returns ``(completion[R,J], rate[R,J])`` with zeros in inactive slots.
    """
    R, J = remaining_mi.shape
    rates = np.zeros((R, J), dtype=np.float64)
    completion = np.zeros((R, J), dtype=np.float64)
    for r in range(R):
        p = int(num_pe[r])
        if p <= 0:
            continue
        eff = mips[r] * avail[r]
        act = active[r] > 0
        n = int(act.sum())
        if n == 0 or eff <= 0:
            continue
        if n <= p:
            per_job = np.full(n, eff)
        else:
            min_per = n // p
            extra = n % p
            max_count = (p - extra) * min_per
            per_job = np.where(
                np.arange(n) < max_count, eff / min_per, eff / (min_per + 1)
            )
        idx = np.flatnonzero(act)
        rates[r, idx] = per_job
        completion[r, idx] = remaining_mi[r, idx] / per_job
    return completion, rates
